"""Distributed quantile tracking: summary guarantee (hypothesis-adversarial,
served through the real store + engine path), merge laws, protocol registry
harness, comm sanity vs naive forwarding, ServicePump deadline executor, and
the mixed matrix+HH+quantile pipeline restart contract.
"""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property-based tests skip gracefully on minimal installs
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:
    hypothesis = None

from repro.core.comm import CommReport
from repro.core.quantiles import (
    QuantileSummary,
    decode_quantile_snapshot,
    encode_quantile_snapshot,
    exact_ranks,
    quantile_query,
    rank_query,
    table_quantile,
    table_rank,
)
from repro.data.synthetic import lowrank_stream, zipfian_stream
from repro.query import (
    PackedQueryService,
    PackedRequest,
    QueryEngine,
    ServicePump,
    ServicePumpError,
    SketchStore,
)
from repro.runtime import (
    EveryKSteps,
    StreamingPipeline,
    TenantQuota,
    create_protocol,
    specs,
)

Q_N, Q_M, Q_EPS = 30_000, 4, 0.05


def _assert_quantile_guarantee(values, weights, serve, eps, slack=0.0):
    """Check eps-approximate quantiles against the achievable-rank criterion.

    ``serve(phi)`` returns the served value; the criterion (see
    docs/protocols.md "The guarantees") is ``R(v) >= phi W - eps W``
    and ``R(v) - mass(v) <= phi W + eps W`` — mass sitting exactly at the
    served value can always absorb the target, so it is not error.
    """
    values = np.asarray(values, np.float32)
    weights = np.asarray(weights, np.float64)
    w_total = float(weights.sum())
    budget = eps * w_total + slack + 1e-5 * w_total + 1e-9
    for phi in np.linspace(0.0, 1.0, 21):
        v = float(serve(phi))
        r_v = float(exact_ranks(values, weights, [v])[0])
        mass = float(weights[values == np.float32(v)].sum())
        target = phi * w_total
        assert r_v >= target - budget, (phi, v, r_v, target)
        assert r_v - mass <= target + budget, (phi, v, r_v, mass, target)


# ---------------------------------------------------------------------------
# the summary itself: guarantee on adversarial streams, merge laws
# ---------------------------------------------------------------------------


ADVERSARIAL = {
    "random": lambda rng, n: rng.normal(size=n),
    "duplicate-heavy": lambda rng, n: rng.integers(0, 8, n).astype(float),
    "one-heavy": lambda rng, n: np.where(rng.uniform(size=n) < 0.9, 3.0,
                                         rng.normal(size=n)),
    "sorted": lambda rng, n: np.sort(rng.normal(size=n)),
    "reversed": lambda rng, n: np.sort(rng.normal(size=n))[::-1],
}


@pytest.mark.parametrize("kind", sorted(ADVERSARIAL))
def test_summary_eps_guarantee_adversarial(kind):
    """Unweighted adversarial streams: every served quantile's rank is
    within eps*N of its target, and the summary's own certificate
    (error_bound) honors the same budget."""
    rng = np.random.default_rng(7)
    n, eps = 20_000, 0.02
    vals = np.asarray(ADVERSARIAL[kind](rng, n), np.float32)
    qs = QuantileSummary(eps)
    qs.extend(vals)
    assert qs.weight == n
    assert qs.error_bound() <= eps * n * (1 + 1e-6)
    tab = qs.table()
    _assert_quantile_guarantee(vals, np.ones(n), lambda phi: table_quantile(
        tab, qs.weight, [phi])[0], eps)
    # rank queries: same budget, same shared table path
    xs = np.concatenate([rng.choice(vals, 64), rng.normal(size=16).astype(np.float32)])
    est = table_rank(tab, xs)
    tru = exact_ranks(vals, np.ones(n), xs)
    assert np.max(np.abs(est - tru)) <= eps * n * (1 + 1e-6) + 1e-3


def test_summary_small_streams_are_exact():
    """Below the compression threshold the summary is lossless."""
    qs = QuantileSummary(0.1)
    vals = [5.0, -2.0, 5.0, 3.25, -2.0, 0.0]
    qs.extend(np.array(vals))
    for x in sorted(set(vals)):
        assert qs.rank(x) == sum(v <= x for v in vals)
    assert qs.quantile(0.0) == -2.0 and qs.quantile(1.0) == 5.0
    assert qs.error_bound() == 0.0
    assert qs.size() == len(set(vals))
    assert qs.serialized_bytes() == 32 * qs.size()


def test_summary_input_validation():
    qs = QuantileSummary(0.1)
    with pytest.raises(ValueError, match="finite"):
        qs.insert(np.inf)
    with pytest.raises(ValueError, match=">= 0"):
        qs.insert(1.0, -2.0)
    qs.insert(1.0, 0.0)  # zero weight: absorbed as a no-op
    assert qs.weight == 0.0 and qs.size() == 0
    with pytest.raises(ValueError):
        QuantileSummary(0.0)
    with pytest.raises(ValueError):
        QuantileSummary(1.5)


def test_summary_merge_order_invariance_of_guarantee():
    """Mergeability laws: any merge order (commuted, re-associated) yields
    an eps-summary of the union with identical total weight."""
    rng = np.random.default_rng(8)
    n, eps = 24_000, 0.05
    vals = np.asarray(rng.normal(size=n) * 10, np.float32)
    chunks = np.array_split(vals, 6)

    def summarize(chunk):
        s = QuantileSummary(eps)
        s.extend(chunk)
        return s

    def merged(order):
        acc = QuantileSummary(eps)
        for i in order:
            acc.merge(summarize(chunks[i]))
        return acc

    for order in (range(6), reversed(range(6)), [3, 0, 5, 1, 4, 2]):
        s = merged(order)
        assert s.weight == pytest.approx(n, rel=1e-6)
        assert s.error_bound() <= eps * n * (1 + 1e-6)
        tab = s.table()
        _assert_quantile_guarantee(
            vals, np.ones(n), lambda phi: table_quantile(tab, s.weight, [phi])[0], eps
        )
    # pairwise-tree association agrees with left fold on the guarantee too
    left, right = summarize(np.concatenate(chunks[:3])), summarize(np.concatenate(chunks[3:]))
    left.merge(right)
    assert left.weight == pytest.approx(n, rel=1e-6)
    assert left.error_bound() <= eps * n * (1 + 1e-6)
    # merging an empty summary is the identity
    s = merged(range(6))
    before = s.table().copy()
    s.merge(QuantileSummary(eps))
    np.testing.assert_array_equal(s.table(), before)


def test_summary_state_dict_round_trip_is_exact():
    rng = np.random.default_rng(9)
    s = QuantileSummary(0.05)
    s.extend(rng.normal(size=5000).astype(np.float32))
    clone = QuantileSummary.from_state(s.state_dict())
    np.testing.assert_array_equal(s.table(), clone.table())
    # continuing both with the same tail stays bit-identical (ckpt contract)
    tail = rng.normal(size=2000).astype(np.float32)
    s.extend(tail)
    clone.extend(tail)
    np.testing.assert_array_equal(s.table(), clone.table())


def test_served_quantiles_property_harness():
    """Hypothesis: adversarial/duplicate-heavy streams served through the
    REAL path — summary -> snapshot codec -> SketchStore -> QueryEngine
    packed-query rows — keep every quantile within eps*N rank error.

    Hypothesis when installed, else a seeded duplicate-heavy sweep over
    the same check."""
    from conftest import run_property

    def check(base, dup_factor, eps, descending):
        vals = np.asarray(base * dup_factor, np.float32)
        if descending:
            vals = np.sort(vals)[::-1]
        n = vals.shape[0]
        qs = QuantileSummary(eps)
        qs.extend(vals)
        store = SketchStore()
        store.publish("q", encode_quantile_snapshot(qs.table()),
                      frob=qs.weight, eps=eps, meta={"workload": "quantile"})
        engine = QueryEngine(store)
        phis = np.linspace(0.0, 1.0, 17)
        res = engine.query_batch(np.stack([quantile_query(p) for p in phis]), tenant="q")
        assert res.path == "quantile" and res.error_bound == pytest.approx(eps * qs.weight)
        for phi, v in zip(phis, res.estimates):
            r_v = float(exact_ranks(vals, np.ones(n), [v])[0])
            mass = float(np.sum(vals == np.float32(v)))
            assert r_v >= phi * n - eps * n - 1e-3 * n - 1e-9
            assert r_v - mass <= phi * n + eps * n + 1e-3 * n + 1e-9
        # rank mode rides the same snapshot within the same budget
        probe = vals[:: max(1, n // 16)]
        ranks = engine.query_batch(np.stack([rank_query(float(v)) for v in probe]),
                                   tenant="q").estimates
        tru = exact_ranks(vals, np.ones(n), probe)
        assert np.max(np.abs(ranks - tru)) <= eps * n + 1e-3 * n + 1e-9

    rng = np.random.default_rng(0)

    def seeded():
        dupes = np.array([0.0, 1.0, -3.5, 7.0], np.float32)
        for _ in range(60):
            n = int(rng.integers(1, 401))
            base = rng.uniform(-1e6, 1e6, n).astype(np.float32)
            forced = rng.random(n) < 0.3  # duplicate-heavy, like the strategy
            base[forced] = dupes[rng.integers(0, 4, int(forced.sum()))]
            yield {
                "base": base.tolist(),
                "dup_factor": int(rng.integers(1, 51)),
                "eps": float(rng.uniform(0.02, 0.3)),
                "descending": bool(rng.integers(0, 2)),
            }

    run_property(
        check,
        given=lambda: {
            "base": st.lists(
                st.one_of(
                    st.floats(min_value=-1e6, max_value=1e6, width=32),
                    st.sampled_from([0.0, 1.0, -3.5, 7.0]),  # forced duplicates
                ),
                min_size=1,
                max_size=400,
            ),
            "dup_factor": st.integers(min_value=1, max_value=50),
            "eps": st.floats(min_value=0.02, max_value=0.3),
            "descending": st.booleans(),
        },
        cases=seeded(),
        max_examples=60,
    )


# ---------------------------------------------------------------------------
# snapshot codec
# ---------------------------------------------------------------------------


def test_quantile_snapshot_codec_round_trip_and_validation():
    tab = np.array([[-1.0, 2.0], [0.5, 4.0], [3.0, 9.0]], np.float32)
    enc = encode_quantile_snapshot(tab)
    vals, ranks = decode_quantile_snapshot(enc)
    np.testing.assert_array_equal(vals, tab[:, 0])
    np.testing.assert_array_equal(ranks, tab[:, 1])
    assert encode_quantile_snapshot(np.zeros((0, 2), np.float32)).shape == (0, 2)
    with pytest.raises(ValueError, match="\\(n, 2\\)"):
        encode_quantile_snapshot(np.zeros((3, 3), np.float32))
    with pytest.raises(ValueError, match="strictly increasing"):
        encode_quantile_snapshot(np.array([[1.0, 1.0], [1.0, 2.0]], np.float32))
    with pytest.raises(ValueError, match="non-decreasing"):
        encode_quantile_snapshot(np.array([[1.0, 5.0], [2.0, 4.0]], np.float32))
    with pytest.raises(ValueError, match="\\(n, 2\\)"):
        decode_quantile_snapshot(np.zeros((2, 3), np.float32))


# ---------------------------------------------------------------------------
# registry: one harness for every registered quantile spec
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mesh():
    return jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))


@pytest.fixture(scope="module")
def q_stream():
    rng = np.random.default_rng(11)
    vals = (rng.normal(size=Q_N) * 10).astype(np.float32)
    weights = rng.uniform(1.0, 50.0, Q_N)
    sites = rng.integers(0, Q_M, Q_N)
    return vals, weights, sites


def _make_quantile(spec, mesh):
    if spec.engine == "event":
        return create_protocol(
            spec.name, engine="event", kind="quantile", m=Q_M, eps=Q_EPS, seed=1
        )
    return create_protocol(
        spec.name, engine="shard", kind="quantile", mesh=mesh, eps=Q_EPS
    )


@pytest.mark.parametrize("spec", specs(kind="quantile"), ids=lambda s: f"{s.engine}-{s.name}")
def test_registry_quantile_harness(spec, q_stream, mesh):
    """Every (engine, protocol) quantile pair: stream batches through the
    uniform interface, then check the rank-error guarantee, message
    accounting vs naive forwarding, the total-weight estimate, the shared
    table query path, and the checkpoint payload round-trip."""
    vals, weights, sites = q_stream
    w_total = float(weights.sum())
    proto = _make_quantile(spec, mesh)
    pairs = np.stack([vals.astype(np.float64), weights], axis=1)
    for i in range(0, Q_N, 10_000):
        if spec.engine == "event":
            proto.step(pairs[i : i + 10_000], sites[i : i + 10_000])
        else:
            proto.step(pairs[i : i + 10_000])
    assert proto.rows_seen == Q_N

    # eps guarantee (err_factor slack for the sampling/shard variants)
    _assert_quantile_guarantee(
        vals, weights, lambda phi: proto.quantile([phi])[0],
        spec.err_factor * Q_EPS,
    )

    # total-weight estimate tracks the true stream weight
    assert 0.5 * w_total <= proto.total_weight() <= 2.0 * w_total

    # comm-bound sanity: beats naive forwarding (one message per item)
    rep = proto.comm_report()
    assert isinstance(rep, CommReport)
    assert 0 < rep.total < Q_N

    # vectorized rank lookups ride the same published-table code path
    probe = vals[:64]
    np.testing.assert_array_equal(proto.rank(probe), table_rank(proto.table(), probe))

    # snapshot encoding is valid store input
    enc = proto.snapshot_matrix()
    assert enc.dtype == np.float32 and enc.shape[1] == 2

    # the jit state's own error certificate honors the coordinator's
    # compress budget (eps/2 internally -> band/2 <= eps/2 * W)
    if spec.engine == "shard":
        from repro.core.quantiles import quant_band

        band = quant_band(proto.state.coord_q)
        assert 0.0 <= band <= 0.5 * Q_EPS * proto.total_weight() * (1 + 1e-5)

    # checkpoint round-trip: a fresh protocol restored from the payload
    # continues the stream identically (the pipeline-restart contract)
    arrays, meta = proto.state_payload()
    clone = _make_quantile(spec, mesh)
    clone.restore_payload({k: np.asarray(v) for k, v in arrays.items()}, meta)
    tail = pairs[:5_000]
    if spec.engine == "event":
        proto.step(tail, sites[:5_000])
        clone.step(tail, sites[:5_000])
    else:
        proto.step(tail)
        clone.step(tail)
    np.testing.assert_array_equal(proto.table(), clone.table())
    assert proto.total_weight() == clone.total_weight()
    assert proto.comm_report() == clone.comm_report()


def test_quantile_rejects_malformed_ingest(mesh):
    """Non-finite values and negative weights are rejected at the ingest
    seam: +/-inf collides with the jit summary's empty-slot sentinel and a
    policy-driven publish failing later would wedge the tenant."""
    for engine in ("event", "shard"):
        kw = {"m": 2} if engine == "event" else {"mesh": mesh}
        proto = create_protocol("P1", engine=engine, kind="quantile", eps=0.5, **kw)
        with pytest.raises(ValueError, match="finite"):
            proto.step(np.array([[np.inf, 1.0]]))
        with pytest.raises(ValueError, match="finite"):
            # finite in f64 but overflows to inf in f32: would silently
            # become the jit summary's empty-slot sentinel
            proto.step(np.array([[1e39, 1.0]]))
        with pytest.raises(ValueError, match=">= 0"):
            proto.step((np.array([1.0]), np.array([-1.0])))
        with pytest.raises(ValueError, match="\\(n, 2\\)"):
            proto.step(np.zeros((3, 4), np.float32))


def test_shard_quantile_duplicate_heavy_publishes_cleanly(mesh):
    """Coordinator merges of equal-valued summaries must fold them into one
    tuple: a duplicate-heavy shard tenant publishes a strictly-increasing
    table (the codec contract) and stays exact across batches."""
    proto = create_protocol("P1", engine="shard", kind="quantile", mesh=mesh, eps=0.01)
    batch = np.stack([np.full(100, 5.0), np.ones(100)], axis=1)
    for _ in range(4):  # several ships of the same single value
        proto.step(batch)
    tab = proto.snapshot_matrix()  # validates strict monotonicity
    assert tab.shape[0] == 1 and tab[0, 0] == 5.0
    assert float(proto.rank([5.0])[0]) == pytest.approx(400.0, rel=1e-6)
    # mixed with distinct values the table stays strictly increasing
    rng = np.random.default_rng(3)
    proto.step(np.stack([rng.normal(size=200), np.ones(200)], axis=1))
    vals = proto.snapshot_matrix()[:, 0]
    assert np.all(np.diff(vals) > 0)


def test_event_quantile_f32_colliding_values_publish_cleanly():
    """Values distinct in f64 but equal in f32 must collapse in the
    published table instead of violating the strictly-increasing codec
    contract (16777217 rounds to 16777216 in float32)."""
    proto = create_protocol("P1", engine="event", kind="quantile", m=1, eps=0.1)
    proto.step(np.array([[16777216.0, 1.0], [16777217.0, 1.0]] * 50))
    tab = proto.snapshot_matrix()
    assert np.all(np.diff(tab[:, 0]) > 0)
    # within eps*W of the exact rank (the unshipped site tail is part of
    # the protocol's eps budget)
    assert float(proto.rank([16777216.0])[0]) == pytest.approx(100.0, abs=0.1 * 100)
    # the sampling variant publishes cleanly on the same colliding stream
    p3 = create_protocol("P3", engine="event", kind="quantile", m=1, eps=0.5, seed=0)
    p3.step(np.array([[16777216.0, 1.0], [16777217.0, 1.0]] * 50))
    tab3 = p3.snapshot_matrix()
    assert tab3.shape[0] >= 1 and np.all(np.diff(tab3[:, 0]) > 0)


def test_quant_insert_empty_batch_is_identity(mesh):
    """An empty (0, 2) ingest batch is a no-op for every quantile engine."""
    from repro.core.quantiles import quant_init, quant_insert

    st = quant_init(16)
    st2 = quant_insert(st, np.zeros(0, np.float32), np.zeros(0, np.float32), 0.1)
    assert st2 is st
    for engine in ("event", "shard"):
        kw = {"m": 2} if engine == "event" else {"mesh": mesh}
        proto = create_protocol("P1", engine=engine, kind="quantile", eps=0.5, **kw)
        proto.step(np.zeros((0, 2), np.float32))
        proto.step(np.array([[1.0, 2.0]], np.float32))
        proto.step(np.zeros((0, 2), np.float32))
        assert float(proto.rank([1.0])[0]) == pytest.approx(2.0)


def test_pipeline_surfaces_dead_pump_instead_of_dropping_deadlines(mesh):
    """A pump that died on an exception must not silently disable deadline
    enforcement: the next ingest raises its error, detaches the pump, and
    cooperative polling resumes."""
    rng = np.random.default_rng(41)
    pipe = StreamingPipeline(mesh, eps=0.1, policy=EveryKSteps(1),
                             pump_interval_s=0.002)
    pipe.add_quantile_tenant("q", eps=0.1, m=2)
    samples = np.stack([rng.normal(size=512).astype(np.float32),
                        np.ones(512, np.float32)], axis=1)
    pipe.ingest("q", samples)
    # Poison the pump: a query for a tenant that can never be answered
    # (pipeline.submit would reject it; go to the service directly).
    pipe.service.submit(np.ones(2, np.float32), tenant="ghost", deadline_s=0.0)
    assert _wait_until(lambda: pipe.pump is not None and not pipe.pump.running)
    with pytest.raises(ServicePumpError) as ei:
        pipe.ingest("q", samples)
    assert isinstance(ei.value.__cause__, KeyError)
    assert pipe.pump is None  # detached: cooperative polling is back on


def test_quantile_shard_matches_event_semantics(q_stream, mesh):
    """Both engines meet the deterministic bound on the same stream."""
    vals, weights, sites = q_stream
    pairs = np.stack([vals.astype(np.float64), weights], axis=1)
    ev = create_protocol("P1", engine="event", kind="quantile", m=1, eps=Q_EPS)
    sh = create_protocol("P1", engine="shard", kind="quantile", mesh=mesh, eps=Q_EPS)
    ev.step(pairs, np.zeros(Q_N, np.int64))
    sh.step(pairs)
    for proto in (ev, sh):
        _assert_quantile_guarantee(
            vals, weights, lambda phi: proto.quantile([phi])[0], 2.0 * Q_EPS
        )


# ---------------------------------------------------------------------------
# engine: packed quantile serving
# ---------------------------------------------------------------------------


@pytest.fixture()
def three_kind_store():
    rng = np.random.default_rng(21)
    store = SketchStore()
    for tenant in ("m1", "m2"):
        store.publish(tenant, rng.normal(size=(12, 32)).astype(np.float32),
                      frob=10.0, eps=0.1)
    store.publish("hh", np.array([[1.0, 5.0], [7.0, 3.0]], np.float32),
                  frob=8.0, eps=0.1, meta={"workload": "hh"})
    qs = QuantileSummary(0.1)
    qs.extend(rng.normal(size=4000).astype(np.float32))
    store.publish("q", encode_quantile_snapshot(qs.table()), frob=qs.weight,
                  eps=0.1, meta={"workload": "quantile"})
    return store


def test_engine_packed_mixed_three_kinds_equals_serial(three_kind_store):
    engine = QueryEngine(three_kind_store)
    rng = np.random.default_rng(22)
    reqs = [
        PackedRequest("m1", rng.normal(size=(5, 32)).astype(np.float32)),
        PackedRequest("q", np.stack([quantile_query(0.5), rank_query(0.0),
                                     quantile_query(0.99)])),
        PackedRequest("m2", rng.normal(size=(3, 32)).astype(np.float32)),
        PackedRequest("hh", np.array([[1.0], [2.0], [7.0]], np.float32)),
    ]
    results = engine.query_packed(reqs)
    assert [r.path for r in results] == ["pallas", "quantile", "pallas", "hh"]
    assert engine.packed_launches == 1  # m1+m2 share (12, 32); lookups launch none
    for req, res in zip(reqs, results):
        serial = engine.query_batch(req.x, tenant=req.tenant)
        np.testing.assert_allclose(res.estimates, serial.estimates, rtol=1e-5)
        assert res.error_bound == serial.error_bound


def test_engine_quantile_query_validation(three_kind_store):
    engine = QueryEngine(three_kind_store)
    with pytest.raises(ValueError, match="\\[mode, arg\\]"):
        engine.query_batch(np.zeros((2, 3), np.float32), tenant="q")
    with pytest.raises(ValueError, match="mode"):
        engine.query_batch(np.array([[7.0, 0.5]], np.float32), tenant="q")


# ---------------------------------------------------------------------------
# ServicePump: the real deadline executor
# ---------------------------------------------------------------------------


def _wait_until(cond, timeout_s=5.0):
    deadline = time.monotonic() + timeout_s
    while not cond() and time.monotonic() < deadline:
        time.sleep(0.002)
    return cond()


def test_service_pump_fires_deadline_without_cooperative_poll(three_kind_store):
    """The acceptance property: an expired deadline is served while the
    submitting thread does nothing — no poll(), no flush(), no ingest."""
    svc = PackedQueryService(QueryEngine(three_kind_store), auto_flush=False)
    with ServicePump(svc, interval_s=0.002) as pump:
        ticket = svc.submit(quantile_query(0.5), tenant="q", deadline_s=0.01)
        assert _wait_until(lambda: ticket.done)
        assert pump.served >= 1 and pump.polls >= 1
    assert not pump.running


def test_service_pump_captures_exceptions_and_reraises_on_stop(three_kind_store):
    """Exception safety: a poll() failure stops the loop, is exposed on
    .error, and stop() re-raises it as ServicePumpError — never silent."""
    svc = PackedQueryService(QueryEngine(three_kind_store), auto_flush=False)
    pump = ServicePump(svc, interval_s=0.002).start()
    # a query nothing can answer: the sweep raises KeyError in the pump
    svc.submit(np.ones(32, np.float32), tenant="unpublished", deadline_s=0.0)
    assert _wait_until(lambda: pump.error is not None)
    assert not pump.running
    with pytest.raises(ServicePumpError) as ei:
        pump.stop()
    assert isinstance(ei.value.__cause__, KeyError)
    # the error was consumed: the pump can be restarted cleanly
    pump.stop()
    assert pump.error is None


def test_service_pump_validation_and_idempotent_start(three_kind_store):
    svc = PackedQueryService(QueryEngine(three_kind_store))
    with pytest.raises(ValueError):
        ServicePump(svc, interval_s=0.0)
    pump = ServicePump(svc, interval_s=0.01)
    assert pump.start() is pump and pump.start() is pump  # idempotent
    assert pump.running
    pump.stop()
    pump.stop()  # idempotent too
    assert not pump.running


def test_pipeline_pump_serves_while_ingest_idle(mesh):
    """Pipeline-owned executor: deadlines hold with zero cooperative
    pumping from the ingest loop (the ROADMAP 'still open' item)."""
    rng = np.random.default_rng(31)
    with StreamingPipeline(mesh, eps=0.1, policy=EveryKSteps(1),
                           pump_interval_s=0.002) as pipe:
        pipe.add_quantile_tenant("lat", eps=0.05, m=2)
        samples = np.stack([rng.lognormal(3, 1, 4000).astype(np.float32),
                            np.ones(4000, np.float32)], axis=1)
        pipe.ingest("lat", samples)
        ticket = pipe.submit("lat", quantile_query(0.9), deadline_s=0.01)
        # ingest is idle from here on; only the pump can resolve the ticket
        assert _wait_until(lambda: ticket.done)
        est, bound, version = ticket.result()
        # bound = eps * hat{W}; hat{W} is the coordinator's received mass,
        # a (1 - eps)-accurate tracker of the true 4000.
        assert version == 1 and bound == pytest.approx(0.05 * 4000, rel=0.1)
        r = float(exact_ranks(samples[:, 0], samples[:, 1], [est])[0])
        assert abs(r - 0.9 * 4000) <= 2 * 0.05 * 4000 + 1
    assert pipe.pump is None  # context exit stopped and detached the pump


# ---------------------------------------------------------------------------
# pipeline: matrix + HH + quantile tenants, fresh-process restart
# ---------------------------------------------------------------------------


def _three_kind_pipeline(mesh):
    """One pipeline hosting all three registered workload kinds."""
    pipe = StreamingPipeline(mesh, eps=0.25, policy=EveryKSteps(1))
    pipe.add_tenant("mat", 16, quota=TenantQuota(max_pending=4, priority=1))
    pipe.add_hh_tenant("clicks", eps=0.05, protocol="P1", engine="event", m=4)
    pipe.add_quantile_tenant("lat-ev", eps=0.05, protocol="P1", engine="event", m=4,
                             quota=TenantQuota(max_pending=8, priority=5))
    pipe.add_quantile_tenant("lat-sh", eps=0.05, protocol="P1", engine="shard")
    return pipe


def _three_kind_feed():
    a = lowrank_stream(1024, 16, rank=3, seed=51)
    keys, w = zipfian_stream(8000, beta=100.0, universe=1000, seed=52)
    hh_pairs = np.stack([keys.astype(np.float32), w.astype(np.float32)], axis=1)
    rng = np.random.default_rng(53)
    q_pairs = np.stack([rng.lognormal(3.0, 1.0, 8000).astype(np.float32),
                        rng.uniform(1.0, 3.0, 8000).astype(np.float32)], axis=1)
    return a, hh_pairs, q_pairs


def _three_kind_answers(pipe, a, hh_pairs, q_pairs):
    """Resume ingest on the second half of every feed, then query all kinds."""
    for i in (2, 3):
        pipe.ingest("mat", jnp.asarray(a[i * 256 : (i + 1) * 256]))
        pipe.ingest("clicks", hh_pairs[i * 2000 : (i + 1) * 2000])
        pipe.ingest("lat-ev", q_pairs[i * 2000 : (i + 1) * 2000])
        pipe.ingest("lat-sh", q_pairs[i * 2000 : (i + 1) * 2000])
    x = np.random.default_rng(54).normal(size=16).astype(np.float32)
    tickets = [
        pipe.submit("mat", x),
        pipe.submit("clicks", np.array([1.0], np.float32)),
        pipe.submit("lat-ev", quantile_query(0.9)),
        pipe.submit("lat-ev", rank_query(30.0)),
        pipe.submit("lat-sh", quantile_query(0.9)),
    ]
    pipe.flush()
    out = [v for t in tickets for v in t.result()]
    out += [float(pipe.stats(t).live_frob) for t in pipe.tenants()]
    out += [float(pipe.stats(t).comm_total) for t in pipe.tenants()]
    out += [float(v) for v in pipe.quantiles("lat-ev", [0.25, 0.5, 0.75, 0.99])]
    return np.array(out, np.float64)


def test_pipeline_three_kinds_restart_fresh_process(mesh, tmp_path):
    """The PR acceptance loop: one pipeline hosts matrix + HH + quantile
    tenants, serves phi-quantiles within the eps envelope through the
    packed path, and after save -> fresh-process load resumes ingest and
    answers bit-identically."""
    from conftest import run_multidevice

    pipe = _three_kind_pipeline(mesh)
    a, hh_pairs, q_pairs = _three_kind_feed()
    for i in (0, 1):  # first half of every stream
        pipe.ingest("mat", jnp.asarray(a[i * 256 : (i + 1) * 256]))
        pipe.ingest("clicks", hh_pairs[i * 2000 : (i + 1) * 2000])
        pipe.ingest("lat-ev", q_pairs[i * 2000 : (i + 1) * 2000])
        pipe.ingest("lat-sh", q_pairs[i * 2000 : (i + 1) * 2000])
    assert {pipe.workload(t) for t in pipe.tenants()} == {"matrix", "hh", "quantile"}

    # served phi-quantiles honor the guarantee through the packed path
    half_vals, half_w = q_pairs[:4000, 0], q_pairs[:4000, 1]
    for tenant in ("lat-ev", "lat-sh"):
        t = pipe.submit(tenant, quantile_query(0.5))
        pipe.flush()
        r = float(exact_ranks(half_vals, half_w, [t.result()[0]])[0])
        w_total = float(half_w.sum())
        assert abs(r - 0.5 * w_total) <= 2 * 0.05 * w_total + 1
    # mixed-workload accessor errors stay typed
    with pytest.raises(ValueError, match="not a quantile tenant"):
        pipe.quantiles("mat", [0.5])
    with pytest.raises(ValueError, match="not a heavy-hitter tenant"):
        pipe.heavy_hitters("lat-ev", 0.1)

    # -- checkpoint, then resume in THIS process --
    ckdir = str(tmp_path / "three_kinds_ck")
    pipe.save(ckdir)
    want = _three_kind_answers(pipe, a, hh_pairs, q_pairs)

    # -- fresh-process restart: load must answer bit-identically --
    tests_dir = os.path.dirname(os.path.abspath(__file__))
    script = f"""
import sys
sys.path.insert(0, {tests_dir!r})
import jax, numpy as np
from repro.runtime import StreamingPipeline
from test_quantiles import _three_kind_answers, _three_kind_feed

mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
pipe = StreamingPipeline.load({ckdir!r}, mesh)
a, hh_pairs, q_pairs = _three_kind_feed()
print("ANSWERS=" + _three_kind_answers(pipe, a, hh_pairs, q_pairs).tobytes().hex())
"""
    out = run_multidevice(script, n_devices=1)
    got_hex = [ln for ln in out.splitlines() if ln.startswith("ANSWERS=")][0]
    got = np.frombuffer(bytes.fromhex(got_hex.removeprefix("ANSWERS=")), np.float64)
    np.testing.assert_array_equal(got, want)


def test_quant_p1_shard_multidevice():
    """QP1 on a real 8-shard mesh: every shard is a paper site, the masked
    all_gather ships summaries, and the folded coordinator meets the rank
    bound at sub-stream communication (like test_distributed.py's matrix
    checks)."""
    from conftest import run_multidevice

    out = run_multidevice(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.distributed import (
    ProtocolConfig, make_protocol_runner, quant_p1_table, quant_p1_w_hat)
from repro.core.quantiles import exact_ranks, table_quantile

m, eps, n = 8, 0.1, 16384
mesh = Mesh(np.array(jax.devices()).reshape(m), ("sites",))
rng = np.random.default_rng(5)
vals = (rng.normal(size=n) * 10).astype(np.float32)
ws = rng.uniform(1.0, 20.0, n).astype(np.float32)
W = float(ws.sum())
cfg = ProtocolConfig(eps=eps, m=m, d=2, axis="sites")
state, step = make_protocol_runner("QP1", cfg, mesh)
batch = 512
for t in range(n // (m * batch)):
    lo, hi = t * m * batch, (t + 1) * m * batch
    state = step(state, (jnp.asarray(vals[lo:hi]), jnp.asarray(ws[lo:hi])))
tab = np.asarray(quant_p1_table(state))
w_hat = quant_p1_w_hat(state)
assert 0.8 * W <= w_hat <= 1.2 * W, (w_hat, W)
worst = 0.0
for phi in np.linspace(0.05, 0.95, 19):
    v = float(table_quantile(tab, w_hat, [phi])[0])
    r = float(exact_ranks(vals, ws, [v])[0])
    worst = max(worst, abs(r - phi * W) / W)
assert worst <= 2 * eps, worst
c = state.comm
total = int(c.scalar_msgs) + int(c.row_msgs) + int(c.broadcast_events) * m
assert 0 < total < n, total
print("OK", worst, total)
"""
    )
    assert "OK" in out
