"""Chaos suite for the fault-injectable cluster transport.

The paper's communication model assumes every site->coordinator message
arrives exactly once; ``repro.cluster.transport`` makes that assumption
checkable.  This file pins the resulting end-to-end property: under ANY
seeded fault schedule (drops, duplicates, delay-reorders, crashes) the
served answers for all four protocol kinds are byte-identical to the
fault-free run, and the transport/router counters account for every
retry — no message unexplained, no row double-counted.

Layout:
  * unmarked unit tests — FaultPlan scripting, Transport primitives,
    RetryPolicy backoff math, CircuitBreaker state machine, the cell's
    per-(tenant, site) dedup window, replica staleness enforcement.
    These run in the fast lane (``-m "not slow"``).
  * ``slow``/``chaos``-marked integration tests — seeded fault sweeps,
    crash-restart recovery through the checkpoint path, replay-queue
    shed, transported rebalance, and the scale_to-vs-parallel-ingest
    race.
"""
import threading

import jax
import numpy as np
import pytest

from repro.cluster import ClusterRouter, PipelineCell, ServingReplica
from repro.cluster import transport as tp
from repro.core.leverage import score_query, subspace_query
from repro.core.quantiles import quantile_query
from repro.query import QueryShedError
from repro.runtime import EveryKSteps
from repro.runtime.policies import RetryPolicy

D = 8

# Zero-delay retries: the chaos suite spins the full retry/backoff
# machinery without ever sleeping (the router's sleep is stubbed too).
FAST_RETRY = RetryPolicy(max_attempts=5, base_s=0.0, cap_s=0.0)


@pytest.fixture(scope="module")
def mesh():
    return jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))


def _router(mesh, n_cells, *, plan=None, **kw):
    """A transported router over fresh cells, tuned for deterministic tests."""
    cells = [
        PipelineCell(f"cell-{i}", mesh, eps=0.2, policy=EveryKSteps(1))
        for i in range(n_cells)
    ]
    transport = tp.Transport(plan=plan)
    defaults = dict(
        transport=transport,
        retry=FAST_RETRY,
        breaker_threshold=2,
        breaker_cooldown_s=0.0,
        staleness_bound=64,
        sleep=lambda s: None,
    )
    defaults.update(kw)
    return ClusterRouter(cells, **defaults), transport


def _register(router):
    router.add_tenant("m0", D, eps=0.2, policy=EveryKSteps(1))
    router.add_hh_tenant("h0", eps=0.05, policy=EveryKSteps(1))
    router.add_quantile_tenant("q0", eps=0.05, policy=EveryKSteps(1))
    router.add_leverage_tenant("v0", D, eps=0.2, policy=EveryKSteps(1))


ALL_KINDS = ("m0", "h0", "q0", "v0")


def _script(n_rounds=6):
    """A deterministic interleaved stream across all four protocol kinds."""
    rng = np.random.default_rng(7)
    out = []
    for _ in range(n_rounds):
        out.append(("m0", rng.normal(size=(16, D)).astype(np.float32)))
        out.append(
            (
                "h0",
                np.stack(
                    [rng.integers(0, 20, 60), rng.uniform(0.5, 2.0, 60)], axis=1
                ).astype(np.float32),
            )
        )
        vals = rng.normal(size=60).astype(np.float32)
        out.append(("q0", np.stack([vals, np.ones(60, np.float32)], axis=1)))
        out.append(("v0", rng.normal(size=(16, D)).astype(np.float32)))
    return out


def _queries():
    rng = np.random.default_rng(99)
    x = rng.normal(size=(4, D)).astype(np.float32)
    return [
        ("m0", x),
        ("h0", np.arange(6, dtype=np.float32)[:, None]),
        ("q0", np.stack([quantile_query(0.25), quantile_query(0.9)])),
        ("v0", np.stack([subspace_query(x[0]), score_query(x[1])])),
    ]


def _settle(router, transport, *, past=0):
    """Heartbeat until every cell is healthy, replay is drained, and the
    transport has consumed at least ``past`` message indices (i.e. the
    fault plan is exhausted and later sends are clean)."""
    for _ in range(200):
        hb = router.heartbeat_all()
        stats = router.stats()
        pending = sum(
            v["replay_pending"] for k, v in stats.items() if k != "_resilience"
        )
        if (
            all(s == "ok" for s in hb.values())
            and pending == 0
            and transport.sends >= past
        ):
            return
    pytest.fail(f"cluster failed to settle: heartbeat={hb}, replay_pending={pending}")


# ---------------------------------------------------------------------------
# FaultPlan
# ---------------------------------------------------------------------------


def test_fault_plan_scripts_one_action_per_index():
    plan = tp.FaultPlan(drop={0}, duplicate={1}, delay={2}, crash={3})
    assert [plan.action(i) for i in range(5)] == [
        "drop",
        "duplicate",
        "delay",
        "crash",
        None,
    ]
    with pytest.raises(ValueError, match="multiple actions"):
        tp.FaultPlan(drop={7}, delay={7})


def test_seeded_fault_plan_is_deterministic_and_bounded():
    a = tp.FaultPlan.seeded(42, 300, p_drop=0.1, p_duplicate=0.1, p_delay=0.1)
    b = tp.FaultPlan.seeded(42, 300, p_drop=0.1, p_duplicate=0.1, p_delay=0.1)
    assert (a.drop, a.duplicate, a.delay) == (b.drop, b.duplicate, b.delay)
    faulted = a.drop | a.duplicate | a.delay
    assert faulted and max(faulted) < 300
    # crash_at wins over whatever band its index fell in
    c = tp.FaultPlan.seeded(42, 300, crash_at=5)
    assert c.action(5) == "crash"
    with pytest.raises(ValueError, match="sum"):
        tp.FaultPlan.seeded(0, 10, p_drop=0.6, p_duplicate=0.5)


# ---------------------------------------------------------------------------
# Transport primitives
# ---------------------------------------------------------------------------


def _echo_transport(plan=None):
    t = tp.Transport(plan=plan)
    seen = []
    t.register("a", lambda env: seen.append(env) or ("ack", env))
    return t, seen


def test_transport_drop_and_duplicate_with_exact_counters():
    t, seen = _echo_transport(tp.FaultPlan(drop={0}, duplicate={1}))
    with pytest.raises(tp.TransportTimeout):
        t.send("a", "m0")
    assert t.send("a", "m1") == ("ack", "m1")
    assert seen == ["m1", "m1"]  # second copy delivered, its reply discarded
    assert t.counters["dropped"] == 1
    assert t.counters["duplicate_deliveries"] == 1
    c = t.counters
    assert t.sends == c["delivered"] + c["dropped"] + c["delayed"] + c["crashed"] + c["down"]
    with pytest.raises(KeyError, match="ghost"):
        t.send("ghost", "m")


def test_transport_delay_is_an_observable_reorder():
    t, seen = _echo_transport(tp.FaultPlan(delay={0}))
    with pytest.raises(tp.TransportTimeout):
        t.send("a", "early")
    assert seen == []  # parked, not delivered
    assert t.send("a", "late") == ("ack", "late")
    assert seen == ["late", "early"]  # late overtook early: a real reorder
    assert t.counters["delayed"] == 1 and t.counters["late_deliveries"] == 1


def test_transport_crash_kills_parked_messages_until_revive():
    t, seen = _echo_transport(tp.FaultPlan(delay={0}, crash={1}))
    with pytest.raises(tp.TransportTimeout):
        t.send("a", "parked")
    with pytest.raises(tp.TransportTimeout):
        t.send("a", "boom")  # crash mid-receive; parked envelope dies with it
    assert t.is_down("a") and seen == []
    with pytest.raises(tp.CellDownError):
        t.send("a", "while-down")
    assert t.counters["crashed"] == 1 and t.counters["down"] == 1
    with pytest.raises(KeyError, match="ghost"):
        t.crash("ghost")
    t.revive("a", lambda env: seen.append(env) or "back")
    assert t.send("a", "hello") == "back"
    assert seen == ["hello"]  # the crashed-away parked envelope never arrives


# ---------------------------------------------------------------------------
# RetryPolicy / CircuitBreaker
# ---------------------------------------------------------------------------


def test_retry_policy_backoff_schedule_and_validation():
    r = RetryPolicy(max_attempts=6, base_s=0.01, cap_s=0.04, jitter=0.5).validate()
    assert r.backoff_s(1) == pytest.approx(0.01)
    assert r.backoff_s(2) == pytest.approx(0.02)
    assert r.backoff_s(3) == pytest.approx(0.04)
    assert r.backoff_s(4) == pytest.approx(0.04)  # capped
    assert r.backoff_s(3, u=1.0) == pytest.approx(0.02)  # full jitter halves it
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0).validate()
    with pytest.raises(ValueError, match="jitter"):
        RetryPolicy(jitter=1.5).validate()
    with pytest.raises(ValueError, match=">= 0"):
        RetryPolicy(base_s=-0.1).validate()
    RetryPolicy(base_s=0.0, cap_s=0.0).validate()  # zero backoff is legal


def test_circuit_breaker_state_machine_under_injected_clock():
    clk = [0.0]
    br = tp.CircuitBreaker(failure_threshold=2, cooldown_s=10.0, clock=lambda: clk[0])
    assert br.allow() and br.state == "closed"
    br.record_failure()
    assert br.state == "closed" and br.allow()
    br.record_failure()
    assert br.state == "open" and br.opens == 1
    assert not br.allow()  # cooldown not elapsed
    clk[0] = 10.0
    assert br.allow() and br.state == "half-open"
    assert not br.allow()  # exactly one in-flight probe
    br.record_failure()  # probe failed: reopen with a fresh cooldown
    assert br.state == "open" and br.opens == 2 and not br.allow()
    clk[0] = 20.0
    assert br.allow()
    br.record_success()
    assert br.state == "closed" and br.failures == 0 and br.allow()
    with pytest.raises(ValueError, match="failure_threshold"):
        tp.CircuitBreaker(failure_threshold=0)


# ---------------------------------------------------------------------------
# Cell dedup window (idempotent, order-restoring ingest)
# ---------------------------------------------------------------------------


def test_cell_dedup_window_applies_exactly_once_in_order(mesh):
    cell = PipelineCell("c", mesh, eps=0.2, policy=EveryKSteps(1), park_bound=2)
    cell.pipeline.add_tenant("t", D, eps=0.2, policy=EveryKSteps(1))
    rng = np.random.default_rng(3)
    b = [rng.normal(size=(8, D)).astype(np.float32) for _ in range(4)]

    ack = cell.ingest_from("t", "s", 1, b[0])
    assert ack.status == "applied" and ack.version == 1
    # a retried delivery (ack was lost) must not double-apply
    assert cell.ingest_from("t", "s", 1, b[0]).status == "duplicate"
    assert cell.pipeline.stats("t").steps == 1
    # out-of-order arrival parks (idempotently) until the gap fills
    assert cell.ingest_from("t", "s", 3, b[2]).status == "parked"
    assert cell.ingest_from("t", "s", 3, b[2]).status == "parked"
    assert cell.parked_count("t") == 1
    ack = cell.ingest_from("t", "s", 2, b[1])  # fills the gap: 2 then 3 apply
    assert ack.status == "applied" and ack.version == 3
    assert cell.parked_count("t") == 0
    assert cell.pipeline.stats("t").steps == 3
    assert cell.dedup_state() == {"t": {"s": 4}}
    # the reassembly buffer is bounded; overflow sheds typed
    assert cell.ingest_from("t", "s", 6, b[3]).status == "parked"
    assert cell.ingest_from("t", "s", 7, b[3]).status == "parked"
    with pytest.raises(tp.IngestShedError):
        cell.ingest_from("t", "s", 8, b[3])
    cell.close()


# ---------------------------------------------------------------------------
# Replica staleness enforcement (the open-circuit serving bound)
# ---------------------------------------------------------------------------


def test_degraded_staleness_bound_is_enforced(mesh):
    cell = PipelineCell("c", mesh, eps=0.2, policy=EveryKSteps(1))
    cell.pipeline.add_tenant("t", D, eps=0.2, policy=EveryKSteps(1))
    rng = np.random.default_rng(4)
    batches = [rng.normal(size=(8, D)).astype(np.float32) for _ in range(5)]
    cell.ingest("t", batches[0])
    replica = ServingReplica(cell, max_versions_behind=2)
    replica.sync("t")
    assert replica.synced_version("t") == 1
    for b in batches[1:]:
        cell.ingest("t", b)  # owner moves on to version 5

    x = np.ones((2, D), np.float32)
    # pinning an already-pulled version answers locally but still records
    # how far ahead the owner is — the replica KNOWS it is 4 behind
    rr = replica.query_batch(x, tenant="t", version=1)
    assert rr.versions_behind == 4
    with pytest.raises(tp.StalenessExceededError) as ei:
        replica.query_degraded(x, tenant="t")
    assert ei.value.tenant == "t"
    assert ei.value.behind == 4 and ei.value.bound == 2
    # after a sync the degraded path serves again, fresh
    replica.sync("t")
    assert replica.query_degraded(x, tenant="t").versions_behind == 0
    # a tenant never synced here cannot be served owner-blind at all
    with pytest.raises(KeyError, match="pre-outage"):
        replica.query_degraded(x, tenant="ghost")
    cell.close()


# ---------------------------------------------------------------------------
# Router retry accounting (fast path)
# ---------------------------------------------------------------------------


def test_router_retries_account_for_every_send(mesh):
    router, transport = _router(mesh, 1, plan=tp.FaultPlan(drop={1}))
    router.add_tenant("t", D, eps=0.2, policy=EveryKSteps(1))
    rows = np.ones((4, D), np.float32)
    assert router.ingest("t", rows).status == "applied"  # index 0: clean
    assert router.ingest("t", rows).status == "applied"  # index 1 dropped, 2 retries
    res = router.stats()["_resilience"]
    assert res["messages"] == 2 and res["retries"] == 1 and res["attempts"] == 3
    assert transport.sends == 3
    assert res["backoff_s"] == 0.0  # zero-delay policy: budget spent is visible
    assert router.cell("cell-0").pipeline.stats("t").steps == 2
    router.close()


def test_replay_queue_overflow_sheds_typed_and_counted(mesh):
    router, transport = _router(mesh, 1, replay_bound=3, breaker_threshold=1)
    router.add_tenant("t", D, eps=0.2, policy=EveryKSteps(1))
    rows = np.ones((4, D), np.float32)
    assert router.ingest("t", rows).status == "applied"
    transport.crash("cell-0")
    for _ in range(3):
        assert router.ingest("t", rows) is None  # parked in the replay queue
    with pytest.raises(tp.IngestShedError) as ei:
        router.ingest("t", rows)
    assert isinstance(ei.value, QueryShedError)  # rides the existing shed path
    assert router.shed_counts()["cell-0"] == 1
    res = router.stats()["_resilience"]
    assert res["ingest_shed"] == 1 and res["parked_ingest"] >= 1
    # revive + heartbeat: the retained batches drain and apply exactly once
    transport.revive("cell-0", router.cell("cell-0").deliver)
    assert router.heartbeat_all() == {"cell-0": "ok"}
    assert router.cell("cell-0").pipeline.stats("t").steps == 4
    assert router.stats()["cell-0"]["replay_pending"] == 0
    router.close()


# ---------------------------------------------------------------------------
# The chaos property: byte-identical answers under any seeded schedule
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.chaos
@pytest.mark.parametrize("seed", [1, 2, 3])
def test_served_answers_identical_under_seeded_faults(mesh, seed):
    n_messages = 160
    script = _script()
    plan = tp.FaultPlan.seeded(seed, n_messages, p_drop=0.15, p_duplicate=0.1, p_delay=0.1)
    ref_router, ref_t = _router(mesh, 2)
    cha_router, cha_t = _router(mesh, 2, plan=plan)
    for router in (ref_router, cha_router):
        _register(router)
        for tenant, rows in script:
            router.ingest(tenant, rows)
    _settle(ref_router, ref_t)
    # burn through the plan with heartbeats so queries run fault-free,
    # then settle: every delayed/parked/retained batch has landed
    while cha_t.sends < n_messages:
        cha_router.heartbeat_all()
    _settle(cha_router, cha_t, past=n_messages)

    # the faults actually fired (the plan wasn't vacuous)
    assert cha_t.counters["dropped"] + cha_t.counters["delayed"] > 0
    # ingest-side state is identical: no row lost, none double-counted
    for t in ALL_KINDS:
        rs = ref_router.cell_for(t).pipeline.stats(t)
        cs = cha_router.cell_for(t).pipeline.stats(t)
        assert (cs.steps, cs.rows, cs.latest_version) == (
            rs.steps,
            rs.rows,
            rs.latest_version,
        ), t
    # served answers are byte-identical for all four protocol kinds
    for a, b in zip(ref_router.query_batch(_queries()), cha_router.query_batch(_queries())):
        assert a.version == b.version and a.error_bound == b.error_bound
        np.testing.assert_array_equal(np.asarray(a.estimates), np.asarray(b.estimates))
    # every send is accounted for, retries included
    for t_ in (ref_t, cha_t):
        c = t_.counters
        assert t_.sends == (
            c["delivered"] + c["dropped"] + c["delayed"] + c["crashed"] + c["down"]
        )
    res = cha_router.stats()["_resilience"]
    assert res["attempts"] == res["messages"] + res["retries"] == cha_t.sends
    ref_router.close()
    cha_router.close()


@pytest.mark.slow
@pytest.mark.chaos
def test_crash_restart_recovers_bit_identical_state(mesh, tmp_path):
    script = _script(8)
    half, three_q = len(script) // 2, 3 * len(script) // 4
    ref_router, ref_t = _router(mesh, 2)
    _register(ref_router)
    for tenant, rows in script:
        ref_router.ingest(tenant, rows)
    _settle(ref_router, ref_t)

    router, transport = _router(mesh, 2)
    _register(router)
    for tenant, rows in script[:half]:
        router.ingest(tenant, rows)
    router.heartbeat_all()  # pre-outage replica sync
    victim = router.placement()["m0"]
    router.checkpoint_cell(victim, str(tmp_path), step=1)
    for tenant, rows in script[half:three_q]:
        router.ingest(tenant, rows)  # applied, but newer than the checkpoint

    transport.crash(victim)
    owned = sorted(t for t, c in router.placement().items() if c == victim)
    assert "m0" in owned
    for tenant, rows in script[three_q:]:
        ack = router.ingest(tenant, rows)
        if router.placement()[tenant] == victim:
            assert ack is None  # parked for replay, not lost
    # queries degrade to the replica for the victim's tenants, within bound
    answers = router.query_batch(_queries())
    assert len(answers) == len(ALL_KINDS) and all(a is not None for a in answers)
    res = router.stats()["_resilience"]
    assert res["degraded_queries"] >= len(owned)
    assert router.degraded_log and all(b <= 64 for _, b in router.degraded_log)

    fresh = PipelineCell(victim, mesh, eps=0.2, policy=EveryKSteps(1))
    with pytest.raises(ValueError, match="expected"):
        router.recover_cell("no-such-cell", fresh, str(tmp_path), step=1)
    reacked = router.recover_cell(victim, fresh, str(tmp_path), step=1)
    assert reacked > 0  # the retained tail replayed into the rebuilt cell
    assert router.stats()["_resilience"]["recoveries"] == 1
    _settle(router, transport)

    for t in ALL_KINDS:
        rs = ref_router.cell_for(t).pipeline.stats(t)
        cs = router.cell_for(t).pipeline.stats(t)
        assert (cs.steps, cs.rows, cs.latest_version) == (
            rs.steps,
            rs.rows,
            rs.latest_version,
        ), t
    for a, b in zip(ref_router.query_batch(_queries()), router.query_batch(_queries())):
        assert a.version == b.version
        np.testing.assert_array_equal(np.asarray(a.estimates), np.asarray(b.estimates))
    ref_router.close()
    router.close()


def _windowed_script(n_rounds=6):
    """Per-round timed batches for one windowed tenant of each kind.

    Event time advances one unit per round for every tenant; with
    ``lateness=0`` the cell's in-seq-order apply (the same property the
    FD byte-identity test already relies on) guarantees no batch is late
    even when the transport delays and reorders deliveries.
    """
    rng = np.random.default_rng(17)
    out = []
    for r in range(n_rounds):
        ts = float(r)
        out.append(("wm", rng.normal(size=(16, D)).astype(np.float32), ts))
        out.append(
            (
                "wh",
                np.stack(
                    [rng.integers(0, 20, 60), rng.uniform(0.5, 2.0, 60)], axis=1
                ).astype(np.float32),
                ts,
            )
        )
        vals = rng.normal(size=60).astype(np.float32)
        out.append(("wq", np.stack([vals, np.ones(60, np.float32)], axis=1), ts))
        out.append(("wv", rng.normal(size=(16, D)).astype(np.float32), ts))
    return out


def _register_windowed(router):
    from repro.runtime.policies import OnWindowClose

    router.add_windowed_tenant(
        "wm", kind="matrix", d=D, window=4.0, buckets=4, policy=OnWindowClose()
    )
    router.add_windowed_tenant("wh", kind="hh", eps=0.05, window=4.0,
                               buckets=4, policy=EveryKSteps(1))
    router.add_windowed_tenant("wq", kind="quantile", eps=0.05, window=4.0,
                               buckets=4, policy=EveryKSteps(1))
    router.add_windowed_tenant("wv", kind="leverage", d=D, window=4.0,
                               buckets=4, policy=EveryKSteps(1))


def _windowed_queries():
    rng = np.random.default_rng(23)
    x = rng.normal(size=(4, D)).astype(np.float32)
    return [
        ("wm", x),
        ("wh", np.arange(6, dtype=np.float32)[:, None]),
        ("wq", np.stack([quantile_query(0.25), quantile_query(0.9)])),
        ("wv", np.stack([subspace_query(x[0]), score_query(x[1])])),
    ]


@pytest.mark.slow
@pytest.mark.chaos
def test_windowed_tenants_identical_under_seeded_faults(mesh):
    """Event-time tenants under the fault schedule: drops/duplicates/
    delay-reorders neither shed in-time rows as late nor skew the
    watermark — sketch state, window bookkeeping, and served answers are
    byte-identical to the fault-free run."""
    n_messages = 120
    script = _windowed_script()
    plan = tp.FaultPlan.seeded(4, n_messages, p_drop=0.15, p_duplicate=0.1, p_delay=0.1)
    ref_router, ref_t = _router(mesh, 2)
    cha_router, cha_t = _router(mesh, 2, plan=plan)
    for router in (ref_router, cha_router):
        _register_windowed(router)
        for tenant, rows, ts in script:
            router.ingest(tenant, rows, ts=ts)
    _settle(ref_router, ref_t)
    while cha_t.sends < n_messages:
        cha_router.heartbeat_all()
    _settle(cha_router, cha_t, past=n_messages)

    assert cha_t.counters["dropped"] + cha_t.counters["delayed"] > 0
    for t in ("wm", "wh", "wq", "wv"):
        ref_pipe = ref_router.cell_for(t).pipeline
        cha_pipe = cha_router.cell_for(t).pipeline
        rs, cs = ref_pipe.stats(t), cha_pipe.stats(t)
        assert (cs.steps, cs.rows, cs.latest_version) == (
            rs.steps,
            rs.rows,
            rs.latest_version,
        ), t
        # no in-time row was ever shed as late, on either run
        assert ref_pipe.stats()["late_rows"] == 0
        assert cha_pipe.stats()["late_rows"] == 0
        # event-time bookkeeping marched identically
        assert cha_pipe.tracker(t).watermark() == ref_pipe.tracker(t).watermark()
        assert cha_pipe.tracker(t).windows_closed() == ref_pipe.tracker(t).windows_closed()
        # published_at rides the watermark, faults or not
        ref_snap = ref_router.cell_for(t).store.get(t)
        cha_snap = cha_router.cell_for(t).store.get(t)
        assert cha_snap.published_at == ref_snap.published_at
    for a, b in zip(
        ref_router.query_batch(_windowed_queries()),
        cha_router.query_batch(_windowed_queries()),
    ):
        assert a.version == b.version and a.error_bound == b.error_bound
        np.testing.assert_array_equal(np.asarray(a.estimates), np.asarray(b.estimates))
    for t_ in (ref_t, cha_t):
        c = t_.counters
        assert t_.sends == (
            c["delivered"] + c["dropped"] + c["delayed"] + c["crashed"] + c["down"]
        )
    ref_router.close()
    cha_router.close()


@pytest.mark.slow
def test_transported_rebalance_moves_dedup_and_replay(mesh):
    router, transport = _router(mesh, 2)
    # 28 tenants is enough that growing the ring provably claims several
    # (t12/t14/... land on cell-2's arcs; the ring hash is deterministic)
    tenants = [f"t{i}" for i in range(28)]
    for t in tenants:
        router.add_tenant(t, D, eps=0.2, policy=EveryKSteps(1))
    rng = np.random.default_rng(5)
    n_batches = 3
    for _ in range(n_batches):
        for t in tenants:
            assert router.ingest(t, rng.normal(size=(8, D)).astype(np.float32)).status == "applied"

    cells = [router.cell(n) for n in router.cells()]
    plan = router.scale_to(
        cells + [PipelineCell("cell-2", mesh, eps=0.2, policy=EveryKSteps(1))]
    )
    assert plan.moves and all(m.dst == "cell-2" for m in plan.moves)
    moved = sorted(m.tenant for m in plan.moves)
    # the seq horizons moved with their tenants...
    for m in plan.moves:
        assert router.cell_for(m.tenant).name == "cell-2"
        assert router.cell("cell-2").dedup_for(m.tenant) == {"site-0": n_batches + 1}
        assert router.cell(m.src).dedup_for(m.tenant) == {}
    # ...and so did the retained replay entries
    assert router.stats()["cell-2"]["replay_retained"] == n_batches * len(moved)
    # the stream continues through the transport, still exactly once
    for t in tenants:
        ack = router.ingest(t, rng.normal(size=(8, D)).astype(np.float32))
        assert ack.status == "applied" and ack.seq == n_batches + 1
    for t in tenants:
        assert router.cell_for(t).pipeline.stats(t).steps == n_batches + 1
    # a stale resend of an already-durable batch is refused by the new owner
    dup = transport.send(
        "cell-2", tp.Ingest(moved[0], "site-0", 1, np.ones((8, D), np.float32))
    )
    assert dup.status == "duplicate"
    router.close()


# ---------------------------------------------------------------------------
# scale_to vs parallel ingest: the rebalance race (direct mode)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_scale_races_parallel_ingest_without_loss_or_double_apply(mesh):
    c0 = PipelineCell("c0", mesh, eps=0.2, policy=EveryKSteps(1))
    c1 = PipelineCell("c1", mesh, eps=0.2, policy=EveryKSteps(1))
    router = ClusterRouter([c0, c1])
    tenants = [f"t{i}" for i in range(6)]
    for t in tenants:
        router.add_tenant(t, D, eps=0.2, policy=EveryKSteps(1))
    rows_per, waves = 8, 12
    rng = np.random.default_rng(11)
    wave_data = [
        [(t, rng.normal(size=(rows_per, D)).astype(np.float32)) for t in tenants]
        for _ in range(waves)
    ]
    started = threading.Event()
    errors = []

    def drive():
        try:
            for i, wave in enumerate(wave_data):
                router.ingest_many(wave, parallel=True)
                if i == 0:
                    started.set()
        except Exception as exc:  # pragma: no cover - surfaced by the assert
            errors.append(exc)
            started.set()

    worker = threading.Thread(target=drive)
    worker.start()
    assert started.wait(timeout=120)
    # grow and shrink while waves are in flight: placement changes twice
    c2 = PipelineCell("c2", mesh, eps=0.2, policy=EveryKSteps(1))
    router.scale_to([c0, c1, c2])
    router.scale_to([c0, c1])
    worker.join(timeout=240)
    assert not worker.is_alive() and not errors
    assert router.rebalances == 2 and router.cells() == ["c0", "c1"]
    # no batch dropped, none double-applied, version streams unbroken
    for t in tenants:
        st = router.cell_for(t).pipeline.stats(t)
        assert st.steps == waves, t
        assert st.rows == waves * rows_per, t
        assert st.latest_version == waves, t
    router.close()


# ---------------------------------------------------------------------------
# Trace integrity + telemetry determinism under seeded faults
# ---------------------------------------------------------------------------


def test_trace_ids_survive_retries_duplicates_and_replay(mesh):
    """Every delivery of an ingest — retry, duplicate, or post-outage
    replay — carries the trace id minted when the batch entered the
    router, so one batch's journey is one trace no matter how the
    transport mangled it."""
    router, transport = _router(mesh, 1, plan=tp.FaultPlan(drop={1}, duplicate={2}))
    router.add_tenant("t", D, eps=0.2, policy=EveryKSteps(1))
    rows = np.ones((4, D), np.float32)
    router.ingest("t", rows)  # message 0: clean
    router.ingest("t", rows)  # index 1 dropped -> retried at 2, duplicated

    tracer = router.obs.tracer
    ingests = tracer.finished(name="router.ingest")
    assert len(ingests) == 2
    tid = ingests[1].trace_id
    # Dropped attempt never reached the cell; the retry delivered twice
    # (primary + duplicate) — both deliveries join the ORIGINAL trace.
    assert len(tracer.finished(trace_id=tid, name="transport.send")) == 2
    assert len(tracer.finished(trace_id=tid, name="cell.deliver")) == 2
    (msg,) = tracer.finished(trace_id=tid, name="transport.message")
    assert [e.name for e in msg.events] == ["retry"]
    assert msg.events[0].attrs["error"] == "TransportTimeout"

    # Crash, park, revive, replay: the drained envelope still carries
    # the trace id of the ingest call that parked it.
    transport.crash("cell-0")
    assert router.ingest("t", rows) is None  # parked for replay
    parked_tid = tracer.finished(name="router.ingest")[-1].trace_id
    assert not tracer.finished(trace_id=parked_tid, name="cell.deliver")
    transport.revive("cell-0", router.cell("cell-0").deliver)
    assert router.heartbeat_all() == {"cell-0": "ok"}
    late = tracer.finished(trace_id=parked_tid, name="cell.deliver")
    assert len(late) == 1  # the replay joined its original trace

    # Global reconciliation: one transport.send span per attempt, exactly.
    res = router.stats()["_resilience"]
    assert res["attempts"] == len(tracer.finished(name="transport.send"))
    assert res["attempts"] == transport.sends
    router.close()


class _TickClock:
    """Deterministic monotonic clock: each call advances 1ms."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 0.001
        return self.t


def _chaos_run_snapshot(mesh, n_messages=80):
    plan = tp.FaultPlan.seeded(5, n_messages, p_drop=0.2, p_duplicate=0.1, p_delay=0.1)
    router, transport = _router(mesh, 2, plan=plan, clock=_TickClock())
    _register(router)
    for tenant, rows in _script(4):
        router.ingest(tenant, rows)
    while transport.sends < n_messages:
        router.heartbeat_all()
    _settle(router, transport, past=n_messages)
    router.query_batch(_queries())
    snap = router.obs.registry.to_json()
    router.close()
    return snap


@pytest.mark.slow
@pytest.mark.chaos
def test_metrics_snapshot_is_deterministic_under_seeded_schedule(mesh):
    """Two runs of the same seeded fault schedule under an injected
    clock serialize byte-identical registries — every counter, label
    series, histogram bucket, and timing sum included."""
    first = _chaos_run_snapshot(mesh)
    second = _chaos_run_snapshot(mesh)
    assert first == second
