"""Paper Section 5: matrix tracking protocols — covariance error + messages.

Includes the Appendix-C P4 negative result: its error must NOT be bounded
by eps (that is the paper's claim, reproduced empirically).
"""
import numpy as np
import pytest

from repro.core.protocols import run_matrix_protocol
from repro.data.synthetic import msd_like, pamap_like, site_assignment

N, M, EPS = 30_000, 10, 0.15


@pytest.fixture(scope="module")
def lowrank():
    a = pamap_like(N, seed=5)
    sites = site_assignment(N, M, seed=5)
    return a, sites, a.T @ a, float(np.sum(a * a))


@pytest.fixture(scope="module")
def highrank():
    a = msd_like(N, seed=6)
    sites = site_assignment(N, M, seed=6)
    return a, sites, a.T @ a, float(np.sum(a * a))


@pytest.mark.parametrize("proto", ["P1", "P2", "P3"])
@pytest.mark.parametrize("data", ["lowrank", "highrank"])
def test_matrix_error_bound(proto, data, request):
    a, sites, ata, frob = request.getfixturevalue(data)
    res = run_matrix_protocol(proto, a, sites, M, EPS, seed=1)
    err = res.covariance_error(ata, frob)
    limit = EPS + 1e-3 if proto in ("P1", "P2") else 1.5 * EPS
    assert err <= limit, (proto, data, err)


def test_matrix_p2_cheapest_deterministic(lowrank):
    a, sites, _, _ = lowrank
    m1 = run_matrix_protocol("P1", a, sites, M, EPS).comm.total(M)
    m2 = run_matrix_protocol("P2", a, sites, M, EPS).comm.total(M)
    assert m2 < m1, "P2 O(m/eps) must beat P1 O(m/eps^2) (paper Table 1)"


def test_matrix_p3wor_beats_p3wr(lowrank):
    """Paper Section 6.2: without-replacement sampling dominates."""
    a, sites, ata, frob = lowrank
    wor = run_matrix_protocol("P3", a, sites, M, EPS, seed=2)
    wr = run_matrix_protocol("P3wr", a, sites, M, EPS, seed=2)
    assert wor.comm.total(M) < wr.comm.total(M)


def test_matrix_p4_negative_result(lowrank):
    """Appendix C: P4's fixed-basis update cannot bound the error by eps."""
    a, sites, ata, frob = lowrank
    p4 = run_matrix_protocol("P4", a, sites, M, EPS, seed=3)
    p2 = run_matrix_protocol("P2", a, sites, M, EPS, seed=3)
    err4 = p4.covariance_error(ata, frob)
    err2 = p2.covariance_error(ata, frob)
    assert err4 > err2, "P4 should be clearly worse than P2"
    assert err4 > EPS, f"P4 err {err4} unexpectedly within eps: negative result not reproduced"


def test_matrix_messages_scale_with_m(lowrank):
    a, sites10, _, _ = lowrank
    sites5 = site_assignment(N, 5, seed=9)
    m5 = run_matrix_protocol("P2", a, sites5, 5, EPS).comm.total(5)
    m10 = run_matrix_protocol("P2", a, sites10, 10, EPS).comm.total(10)
    assert m5 < m10, "P2 communication is linear in m (paper Fig 2c/3c)"


def test_matrix_all_beat_naive(lowrank):
    a, sites, _, _ = lowrank
    for proto in ["P2", "P3"]:
        msgs = run_matrix_protocol(proto, a, sites, M, EPS).comm.total(M)
        assert msgs < N / 5, (proto, msgs)
