"""End-to-end system behaviour: train -> checkpoint -> crash -> restore ->
identical continuation; event-driven vs shard_map engines agree; data
pipeline prefetch."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from conftest import run_multidevice
from repro.ckpt import AsyncCheckpointer, latest_step, restore
from repro.data import Prefetcher, TokenStream
from repro.models.config import ModelConfig
from repro.models.transformer import LM
from repro.train.step import TrainConfig, init_train_state, make_train_step

CFG = ModelConfig(
    name="sys", family="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256, dtype="float32", remat="none",
)


def test_end_to_end_train_crash_resume():
    lm = LM(CFG)
    tcfg = TrainConfig(peak_lr=5e-3, warmup_steps=5, total_steps=50)
    ds = TokenStream(global_batch=8, seq_len=64, vocab=256, seed=1)
    step = jax.jit(make_train_step(lm, tcfg))

    with tempfile.TemporaryDirectory() as d:
        ckpt = AsyncCheckpointer(d, keep=2)
        state = init_train_state(lm, jax.random.key(0), tcfg)
        reference_losses = []
        for i in range(20):
            state, m = step(state, {"tokens": jnp.asarray(ds.batch_at(i)["tokens"])})
            reference_losses.append(float(m["loss"]))
            if (i + 1) % 5 == 0:
                ckpt.save(i + 1, state)
        ckpt.wait()
        final_reference = state

        # "crash": rebuild everything from the latest checkpoint
        last = latest_step(d)
        assert last == 20
        fresh = init_train_state(lm, jax.random.key(99), tcfg)  # wrong weights
        restored, _ = restore(d, last, fresh)
        for a, b in zip(jax.tree.leaves(final_reference), jax.tree.leaves(restored)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)

        # resumed continuation == uninterrupted continuation
        cont_a, ma = step(restored, {"tokens": jnp.asarray(ds.batch_at(20)["tokens"])})
        cont_b, mb = step(final_reference, {"tokens": jnp.asarray(ds.batch_at(20)["tokens"])})
        assert float(ma["loss"]) == float(mb["loss"])
        # old checkpoints were garbage-collected to `keep`
        assert latest_step(d) == 20


def test_prefetcher_matches_direct_batches():
    ds = TokenStream(global_batch=4, seq_len=32, vocab=128, seed=7)
    pf = Prefetcher(ds, start_step=3, depth=2)
    try:
        for want_step in range(3, 8):
            got_step, batch = pf.next()
            assert got_step == want_step
            np.testing.assert_array_equal(batch["tokens"], ds.batch_at(want_step)["tokens"])
    finally:
        pf.close()


def test_host_sharded_pipeline_partitions_batch():
    parts = [
        TokenStream(global_batch=8, seq_len=16, vocab=64, seed=3, host_index=i, host_count=4)
        for i in range(4)
    ]
    for p in parts:
        assert p.host_batch == 2
    # each host's batch is deterministic and distinct
    b0 = parts[0].batch_at(0)["tokens"]
    b1 = parts[1].batch_at(0)["tokens"]
    assert not np.array_equal(b0, b1)
    np.testing.assert_array_equal(b0, parts[0].batch_at(0)["tokens"])


def test_engines_agree_event_driven_vs_shard_map():
    """The paper-exact engine and the TPU super-step engine must agree on
    the tracked spectrum (same protocol, same guarantee)."""
    out = run_multidevice(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.distributed import ProtocolConfig, make_protocol_runner
from repro.core.protocols import run_matrix_protocol
from repro.core import fd as fdlib

m, d, eps = 8, 24, 0.25
rng = np.random.default_rng(4)
u = rng.normal(size=(4096, 4)) * np.array([10.0, 5.0, 2.0, 1.0])
A = (u @ rng.normal(size=(4, d))).astype(np.float32)
ata = A.T @ A; frob = float(np.sum(A * A))

ev = run_matrix_protocol("P2", A, rng.integers(0, m, size=4096), m, eps)
err_ev = ev.covariance_error(ata, frob)

mesh = Mesh(np.array(jax.devices()).reshape(m), ("sites",))
cfg = ProtocolConfig(eps=eps, m=m, d=d, axis="sites", l_site=16, l_coord=32)
state, step = make_protocol_runner("P2", cfg, mesh)
for t in range(4096 // (m * 64)):
    state = step(state, jnp.asarray(A[t*m*64:(t+1)*m*64]))
B = np.asarray(fdlib.fd_matrix(state.coord_fd))
err_sm = float(np.linalg.norm(ata - B.T @ B, 2) / frob)
assert err_ev <= eps + 1e-3, err_ev
assert err_sm <= eps + 1e-3, err_sm
print("OK", err_ev, err_sm)
"""
    )
    assert "OK" in out
