"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property-based tests skip gracefully on minimal installs
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:
    hypothesis = None

from repro.kernels.ops import fd_gram, fd_project, flash_attention, quadform
from repro.kernels.ref import ref_attention, ref_fd_gram, ref_fd_project, ref_quadform

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("l,d", [(8, 128), (16, 256), (32, 512), (17, 300), (64, 1024), (128, 2048)])
def test_fd_gram_sweep(l, d, dtype):
    b = jnp.asarray(RNG.normal(size=(l, d)), dtype)
    got = np.asarray(fd_gram(b))
    want = np.asarray(ref_fd_gram(b))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * d)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("l,d", [(8, 128), (32, 512), (17, 300), (64, 1024)])
def test_fd_project_sweep(l, d, dtype):
    b = jnp.asarray(RNG.normal(size=(l, d)), dtype)
    w = jnp.asarray(RNG.uniform(size=(l,)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(l, l)), jnp.float32)
    got = np.asarray(fd_project(w, u, b).astype(jnp.float32))
    want = np.asarray(ref_fd_project(w, u, b).astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * np.sqrt(l * d))


@pytest.mark.parametrize(
    "b,hq,hkv,s,dh,window,softcap",
    [
        (1, 4, 2, 256, 64, 0, 0.0),
        (2, 4, 1, 128, 32, 0, 0.0),
        (1, 2, 2, 256, 64, 96, 0.0),
        (1, 4, 4, 200, 64, 0, 30.0),  # non-block-multiple seq (padding path)
        (1, 8, 2, 512, 128, 128, 0.0),
        (1, 3, 3, 192, 64, 0, 0.0),  # odd head count
    ],
)
def test_flash_attention_sweep(b, hq, hkv, s, dh, window, softcap):
    q = jnp.asarray(RNG.normal(size=(b, hq, s, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, dh)), jnp.float32)
    got = flash_attention(
        q, k, v, causal=True, window=window, logit_softcap=softcap, block_q=64, block_kv=64
    )
    want = ref_attention(q, k, v, causal=True, window=window, logit_softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-4)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.normal(size=(1, 4, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    got = flash_attention(q, k, v, block_q=64, block_kv=64).astype(jnp.float32)
    want = ref_attention(q, k, v).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("l,d,n", [(8, 128, 128), (32, 512, 256), (17, 300, 37), (64, 1024, 1024)])
def test_quadform_sweep(l, d, n, dtype):
    b = jnp.asarray(RNG.normal(size=(l, d)), dtype)
    x = jnp.asarray(RNG.normal(size=(n, d)), dtype)
    got = np.asarray(quadform(b, x))
    want = np.asarray(ref_quadform(b, x))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * d)


def test_fd_gram_property():
    """Gram kernel is exact-psd and scale-consistent for any (L, d)."""
    pytest.importorskip("hypothesis")

    @hypothesis.given(
        l=st.integers(2, 40),
        d=st.integers(2, 300),
        scale=st.floats(0.1, 100.0),
    )
    @hypothesis.settings(max_examples=20, deadline=None)
    def check(l, d, scale):
        b = jnp.asarray(RNG.normal(size=(l, d)) * scale, jnp.float32)
        g = np.asarray(fd_gram(b))
        np.testing.assert_allclose(g, g.T, rtol=1e-5, atol=1e-3 * scale**2)
        want = np.asarray(ref_fd_gram(b))
        np.testing.assert_allclose(g, want, rtol=1e-4, atol=1e-3 * scale**2 * d)

    check()
