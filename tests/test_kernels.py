"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property-based tests skip gracefully on minimal installs
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:
    hypothesis = None

from repro.kernels.ops import (
    fd_gram,
    fd_project,
    fd_shrink,
    fd_spectra,
    flash_attention,
    quadform,
)
from repro.kernels.ref import ref_attention, ref_fd_gram, ref_fd_project, ref_quadform

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("l,d", [(8, 128), (16, 256), (32, 512), (17, 300), (64, 1024), (128, 2048)])
def test_fd_gram_sweep(l, d, dtype):
    b = jnp.asarray(RNG.normal(size=(l, d)), dtype)
    got = np.asarray(fd_gram(b, path="pallas"))
    want = np.asarray(ref_fd_gram(b))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * d)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("l,d", [(8, 128), (32, 512), (17, 300), (64, 1024)])
def test_fd_project_sweep(l, d, dtype):
    b = jnp.asarray(RNG.normal(size=(l, d)), dtype)
    w = jnp.asarray(RNG.uniform(size=(l,)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(l, l)), jnp.float32)
    got = np.asarray(fd_project(w, u, b, path="pallas").astype(jnp.float32))
    want = np.asarray(ref_fd_project(w, u, b).astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * np.sqrt(l * d))


@pytest.mark.parametrize(
    "b,hq,hkv,s,dh,window,softcap",
    [
        (1, 4, 2, 256, 64, 0, 0.0),
        (2, 4, 1, 128, 32, 0, 0.0),
        (1, 2, 2, 256, 64, 96, 0.0),
        (1, 4, 4, 200, 64, 0, 30.0),  # non-block-multiple seq (padding path)
        (1, 8, 2, 512, 128, 128, 0.0),
        (1, 3, 3, 192, 64, 0, 0.0),  # odd head count
    ],
)
def test_flash_attention_sweep(b, hq, hkv, s, dh, window, softcap):
    q = jnp.asarray(RNG.normal(size=(b, hq, s, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, dh)), jnp.float32)
    got = flash_attention(
        q, k, v, causal=True, window=window, logit_softcap=softcap, block_q=64, block_kv=64
    )
    want = ref_attention(q, k, v, causal=True, window=window, logit_softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-4)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.normal(size=(1, 4, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    got = flash_attention(q, k, v, block_q=64, block_kv=64).astype(jnp.float32)
    want = ref_attention(q, k, v).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("l,d,n", [(8, 128, 128), (32, 512, 256), (17, 300, 37), (64, 1024, 1024)])
def test_quadform_sweep(l, d, n, dtype):
    b = jnp.asarray(RNG.normal(size=(l, d)), dtype)
    x = jnp.asarray(RNG.normal(size=(n, d)), dtype)
    got = np.asarray(quadform(b, x))
    want = np.asarray(ref_quadform(b, x))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * d)


@pytest.mark.parametrize("t,l,d", [(1, 8, 64), (4, 16, 128), (3, 17, 100)])
def test_fd_shrink_paths_agree(t, l, d):
    """Fused-pallas and XLA fd_shrink agree to 1e-5 on B'^T B' and delta."""
    b = jnp.asarray(RNG.normal(size=(t, 2 * l, d)), jnp.float32)
    out_p, delta_p = fd_shrink(b, path="pallas")
    out_x, delta_x = fd_shrink(b, path="xla")
    # eigh sign/rotation freedom means rows can differ; the sketch Gram
    # and the shrink offset are the served quantities and must match.
    for gp, gx in zip(out_p, out_x):
        np.testing.assert_allclose(
            np.asarray(gp.T @ gp), np.asarray(gx.T @ gx), rtol=1e-4, atol=1e-3
        )
    np.testing.assert_allclose(np.asarray(delta_p), np.asarray(delta_x), rtol=1e-4, atol=1e-5)


def test_fd_shrink_matches_core_single():
    """Batched fd_shrink reproduces core.fd.fd_shrink on an unstacked buffer."""
    from repro.core.fd import fd_shrink as core_shrink

    b = jnp.asarray(RNG.normal(size=(32, 96)), jnp.float32)
    out, delta = fd_shrink(b, path="xla")
    want, want_delta = core_shrink(b)
    np.testing.assert_allclose(
        np.asarray(out.T @ out), np.asarray(want.T @ want), rtol=1e-4, atol=1e-3
    )
    np.testing.assert_allclose(float(delta), float(want_delta), rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("t,l,d", [(1, 8, 64), (4, 20, 128)])
def test_fd_spectra_vs_svd(t, l, d):
    """Batched spectrum refresh matches per-sketch SVD singular values/dirs."""
    b = jnp.asarray(RNG.normal(size=(t, l, d)), jnp.float32)
    for path in ("pallas", "xla"):
        s, vt = fd_spectra(b, path=path)
        for i in range(t):
            u_, s_, vt_ = np.linalg.svd(np.asarray(b[i]), full_matrices=False)
            np.testing.assert_allclose(np.asarray(s[i]), s_, rtol=1e-4, atol=1e-4)
            # directions match up to per-row sign
            dots = np.abs(np.sum(np.asarray(vt[i]) * vt_, axis=1))
            np.testing.assert_allclose(dots, 1.0, atol=1e-3)


def test_fd_spectra_rejects_fat():
    with pytest.raises(ValueError):
        fd_spectra(jnp.zeros((2, 64, 32)))


@pytest.mark.parametrize("l,d", [(8, 128), (17, 300)])
def test_fd_gram_project_path_dispatch(l, d):
    """path="auto"|"pallas"|"xla" agree to 1e-5; bad path raises."""
    b = jnp.asarray(RNG.normal(size=(l, d)), jnp.float32)
    w = jnp.asarray(RNG.uniform(size=(l,)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(l, l)), jnp.float32)
    g = {p: np.asarray(fd_gram(b, path=p)) for p in ("auto", "pallas", "xla")}
    np.testing.assert_allclose(g["pallas"], g["xla"], rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(g["auto"], g["xla"], rtol=1e-6, atol=1e-6)
    pr = {p: np.asarray(fd_project(w, u, b, path=p)) for p in ("auto", "pallas", "xla")}
    np.testing.assert_allclose(pr["pallas"], pr["xla"], rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(pr["auto"], pr["xla"], rtol=1e-6, atol=1e-6)
    with pytest.raises(ValueError):
        fd_gram(b, path="cuda")
    with pytest.raises(ValueError):
        fd_shrink(jnp.zeros((2, 16, 64)), path="cuda")


def test_fd_gram_property():
    """Gram kernel is exact-psd and scale-consistent for any (L, d).

    Hypothesis when installed, else a seeded sweep over the same check.
    """
    from conftest import run_property

    def check(l, d, scale):
        b = jnp.asarray(RNG.normal(size=(l, d)) * scale, jnp.float32)
        g = np.asarray(fd_gram(b, path="pallas"))
        np.testing.assert_allclose(g, g.T, rtol=1e-5, atol=1e-3 * scale**2)
        want = np.asarray(ref_fd_gram(b))
        np.testing.assert_allclose(g, want, rtol=1e-4, atol=1e-3 * scale**2 * d)

    rng = np.random.default_rng(0)
    run_property(
        check,
        given=lambda: {
            "l": st.integers(2, 40),
            "d": st.integers(2, 300),
            "scale": st.floats(0.1, 100.0),
        },
        cases=(
            {
                "l": int(rng.integers(2, 41)),
                "d": int(rng.integers(2, 301)),
                "scale": float(rng.uniform(0.1, 100.0)),
            }
            for _ in range(20)
        ),
        max_examples=20,
    )
