"""Cluster layer: hash ring placement, cell migration, router fan-out,
rebalance determinism, and replica staleness.

The load-bearing property pinned here is bit-identity: a tenant answers
the same packed query with the same bytes whether it lives on a bare
``StreamingPipeline``, a 1-cell cluster, a 4-cell cluster, or has been
moved between cells mid-stream.  Each tenant lives wholly on one cell
and ``quadform_packed`` per-tenant output slices are independent of pack
composition, so sharding must be invisible to answers.
"""
import tempfile

import jax
import numpy as np
import pytest

from repro import ckpt
from repro.cluster import (
    ClusterRouter,
    HashRing,
    PipelineCell,
    ServingReplica,
    rebalance_plan,
)
from repro.core.leverage import score_query, subspace_query
from repro.core.quantiles import quantile_query
from repro.query import PackedRequest, QueryShedError
from repro.runtime import EveryKSteps, StreamingPipeline, TenantQuota

D = 16


@pytest.fixture(scope="module")
def mesh():
    return jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))


def _matrix_batches(seed, n_batches=3, rows=32):
    rng = np.random.default_rng(seed)
    return [rng.normal(size=(rows, D)).astype(np.float32) for _ in range(n_batches)]


def _weighted_pairs(seed, n_batches=3, rows=100, vocab=30):
    rng = np.random.default_rng(seed)
    return [
        np.stack(
            [rng.integers(0, vocab, rows), rng.uniform(0.5, 2.0, rows)], axis=1
        ).astype(np.float32)
        for _ in range(n_batches)
    ]


def _build_mixed(target):
    """Register + drive the same four-kind tenant load on any target that
    exposes the pipeline add/ingest surface (pipeline, cell, or router)."""
    for i in range(4):
        target.add_tenant(f"mat-{i}", D, eps=0.2, policy=EveryKSteps(1))
    target.add_hh_tenant("hh-a", eps=0.05, policy=EveryKSteps(1))
    target.add_quantile_tenant("qq-a", eps=0.05, policy=EveryKSteps(1))
    target.add_leverage_tenant("lev-a", D, eps=0.2, policy=EveryKSteps(1))
    for i in range(4):
        for b in _matrix_batches(seed=10 + i):
            target.ingest(f"mat-{i}", b)
    for b in _weighted_pairs(seed=20):
        target.ingest("hh-a", b)
    qrng = np.random.default_rng(21)
    for _ in range(3):
        vals = qrng.normal(size=100).astype(np.float32)
        target.ingest("qq-a", np.stack([vals, np.ones(100, np.float32)], axis=1))
    for b in _matrix_batches(seed=22):
        target.ingest("lev-a", b)


def _mixed_queries():
    rng = np.random.default_rng(99)
    x = rng.normal(size=(5, D)).astype(np.float32)
    return [(f"mat-{i}", x) for i in range(4)] + [
        ("hh-a", np.arange(6, dtype=np.float32)[:, None]),
        ("qq-a", np.stack([quantile_query(0.25), quantile_query(0.9)])),
        ("lev-a", np.stack([subspace_query(x[0]), score_query(x[1])])),
    ]


# ---------------------------------------------------------------------------
# hash ring
# ---------------------------------------------------------------------------


def test_ring_is_deterministic_and_balanced():
    tenants = [f"tenant-{i}" for i in range(200)]
    r1 = HashRing(["a", "b", "c", "d"])
    r2 = HashRing(["d", "c", "b", "a"])  # order-insensitive
    assert r1 == r2
    assert [r1.place(t) for t in tenants] == [r2.place(t) for t in tenants]
    spread = r1.spread(tenants)
    assert sum(spread.values()) == 200
    assert all(v > 0 for v in spread.values())  # no starved cell at 64 vnodes


def test_grow_by_one_moves_tenants_only_onto_the_new_cell():
    tenants = {f"tenant-{i}": None for i in range(200)}
    old = HashRing(["a", "b", "c"])
    placement = {t: old.place(t) for t in tenants}
    plan = rebalance_plan(old, old.with_cells(["a", "b", "c", "d"]), placement)
    assert plan.moves  # a new cell always claims some arcs at 64 vnodes
    assert all(m.dst == "d" for m in plan.moves)
    assert 0 < plan.moved_fraction < 1
    assert len(plan.moves) + plan.unmoved == 200
    # shrink back: exactly the same tenants return, each to its old owner
    back = rebalance_plan(
        old.with_cells(["a", "b", "c", "d"]),
        old,
        {t: ("d" if any(m.tenant == t for m in plan.moves) else c)
         for t, c in placement.items()},
    )
    assert {m.tenant for m in back.moves} == {m.tenant for m in plan.moves}
    assert all(placement[m.tenant] == m.dst for m in back.moves)


def test_ring_rejects_empty_and_duplicate_cells():
    with pytest.raises(ValueError):
        HashRing([])
    with pytest.raises(ValueError):
        HashRing(["a", "a"])


# ---------------------------------------------------------------------------
# determinism: bare pipeline == 1-cell == 4-cell, per tenant, bit-identical
# ---------------------------------------------------------------------------


def test_cluster_matches_single_pipeline_bit_identically(mesh):
    single = StreamingPipeline(mesh, eps=0.2, policy=EveryKSteps(1))
    _build_mixed(single)
    queries = _mixed_queries()
    base = single.engine.query_packed([PackedRequest(t, q) for t, q in queries])

    for n_cells in (1, 4):
        cells = [
            PipelineCell(f"cell-{i}", mesh, eps=0.2, policy=EveryKSteps(1))
            for i in range(n_cells)
        ]
        with ClusterRouter(cells) as router:
            _build_mixed(router)
            if n_cells == 4:  # the load must actually shard to mean anything
                assert len({c for c in router.placement().values()}) > 1
            got = router.query_batch(queries)
            assert [r.tenant for r in got] == [t for t, _ in queries]
            for b, g in zip(base, got):
                assert b.version == g.version
                assert b.error_bound == g.error_bound
                np.testing.assert_array_equal(b.estimates, g.estimates)


def test_rebalance_round_trip_preserves_answers(mesh):
    cells = [PipelineCell(f"cell-{i}", mesh, eps=0.2, policy=EveryKSteps(1))
             for i in range(2)]
    router = ClusterRouter(cells)
    _build_mixed(router)
    queries = _mixed_queries()
    before = router.query_batch(queries)
    placement_before = router.placement()

    grown = cells + [PipelineCell("cell-2", mesh, eps=0.2, policy=EveryKSteps(1))]
    plan = router.scale_to(grown)
    assert all(m.dst == "cell-2" for m in plan.moves)
    mid = router.query_batch(queries)
    shrunk_plan = router.scale_to(cells)  # round trip: back to the old ring
    assert {m.tenant for m in shrunk_plan.moves} == {m.tenant for m in plan.moves}
    after = router.query_batch(queries)

    assert router.placement() == placement_before
    assert router.rebalances == 2
    for b, m, a in zip(before, mid, after):
        assert b.version == m.version == a.version
        np.testing.assert_array_equal(b.estimates, m.estimates)
        np.testing.assert_array_equal(b.estimates, a.estimates)
    # moved tenants keep ingesting and publishing after the round trip
    snap = router.ingest("mat-0", _matrix_batches(seed=77, n_batches=1)[0])
    assert snap is not None and snap.version == before[0].version + 1
    router.close()


def test_scale_to_refuses_name_collision_with_different_object(mesh):
    cell = PipelineCell("cell-0", mesh, eps=0.2)
    router = ClusterRouter([cell])
    impostor = PipelineCell("cell-0", mesh, eps=0.2)
    with pytest.raises(ValueError, match="live state"):
        router.scale_to([impostor])
    with pytest.raises(ValueError, match="duplicate"):
        router.scale_to([cell, cell])


# ---------------------------------------------------------------------------
# cell migration mechanics
# ---------------------------------------------------------------------------


def test_export_import_moves_live_tenant_bit_identically(mesh):
    src = PipelineCell("src", mesh, eps=0.2, policy=EveryKSteps(1))
    dst = PipelineCell("dst", mesh, eps=0.2, policy=EveryKSteps(1))
    src.pipeline.add_tenant("t", D, eps=0.2, policy=EveryKSteps(2))
    batches = _matrix_batches(seed=3, n_batches=5)
    for b in batches[:3]:
        src.ingest("t", b)

    payload = src.export_tenant("t")
    assert payload["format"] == "tenant-export-v1"
    dst.import_tenant(payload)
    src.remove_tenant("t")
    assert src.tenants() == [] and dst.tenants() == ["t"]
    assert src.store.tenants() == []

    # mid-policy state (steps_since_publish with EveryKSteps(2)) survived:
    # continuing the same stream publishes the same versions with the same bytes
    ref = StreamingPipeline(mesh, eps=0.2)
    ref.add_tenant("t", D, eps=0.2, policy=EveryKSteps(2))
    for b in batches:
        ref_snap = ref.ingest("t", b)
    for b in batches[3:]:
        moved_snap = dst.ingest("t", b)
    assert (moved_snap is None) == (ref_snap is None)
    np.testing.assert_array_equal(
        dst.store.get("t").matrix, ref.store.get("t").matrix
    )
    assert dst.store.versions("t") == ref.store.versions("t")


def test_export_refuses_pending_and_import_refuses_duplicates(mesh):
    cell = PipelineCell("c", mesh, eps=0.2, policy=EveryKSteps(1))
    cell.pipeline.add_tenant("t", D, eps=0.2)
    cell.ingest("t", _matrix_batches(seed=4, n_batches=1)[0])
    cell.submit("t", np.ones(D, np.float32))
    with pytest.raises(RuntimeError, match="pending"):
        cell.export_tenant("t")
    cell.flush()
    payload = cell.export_tenant("t")
    with pytest.raises(ValueError, match="already registered"):
        cell.import_tenant(payload)
    cell.submit("t", np.ones(D, np.float32))
    with pytest.raises(RuntimeError, match="pending"):
        cell.remove_tenant("t")
    cell.flush()


def test_read_tenant_export_from_checkpoint(mesh):
    cell = PipelineCell("c", mesh, eps=0.2, policy=EveryKSteps(1))
    _build_mixed(cell.pipeline)
    with tempfile.TemporaryDirectory() as tmp:
        cell.save(tmp, step=7)
        payload = StreamingPipeline.read_tenant_export(tmp, "lev-a")
        live = cell.export_tenant("lev-a")
        assert payload["workload"] == live["workload"] == "leverage"
        assert payload["ctor"] == live["ctor"]
        assert payload["latest_version"] == live["latest_version"]
        for k, v in live["arrays"].items():
            np.testing.assert_array_equal(payload["arrays"][k], v)
        assert payload["store_extra"] == live["store_extra"]

        fresh = PipelineCell("fresh", mesh, eps=0.2)
        fresh.import_tenant(payload)
        q = np.stack([subspace_query(np.ones(D, np.float32))])
        a = cell.engine.query_batch(q, tenant="lev-a")
        b = fresh.engine.query_batch(q, tenant="lev-a")
        np.testing.assert_array_equal(a.estimates, b.estimates)

        with pytest.raises(KeyError, match="ghost"):
            StreamingPipeline.read_tenant_export(tmp, "ghost")


def test_ckpt_read_subset_verifies_and_rejects_missing(tmp_path):
    tree = {"a": np.arange(6, dtype=np.float32), "b": np.ones((2, 3), np.int32)}
    ckpt.save(str(tmp_path), 1, tree)
    sub = ckpt.read_subset(str(tmp_path), 1, ["b"])
    assert list(sub) == ["b"]
    np.testing.assert_array_equal(sub["b"], tree["b"])
    with pytest.raises(KeyError, match="nope"):
        ckpt.read_subset(str(tmp_path), 1, ["a", "nope"])


def test_ckpt_read_subset_raises_on_corrupt_or_truncated_leaf(tmp_path):
    import io
    import os

    tree = {"a": np.arange(64, dtype=np.float32), "b": np.ones((2, 3), np.int32)}
    path = ckpt.save(str(tmp_path), 1, tree)
    manifest = ckpt.read_manifest(str(tmp_path), 1)
    a_file = os.path.join(path, manifest["leaves"]["a"]["file"])

    # silent substitution: validly-compressed bytes of the WRONG content —
    # only the per-leaf sha256 can catch this, and it must name the leaf
    buf = io.BytesIO()
    np.save(buf, np.zeros(64, np.float32), allow_pickle=False)
    raw = buf.getvalue()
    if manifest["codec"] == "zstd":
        import zstandard

        forged = zstandard.ZstdCompressor(level=3).compress(raw)
    else:
        import zlib

        forged = zlib.compress(raw, 6)
    with open(a_file, "wb") as f:
        f.write(forged)
    with pytest.raises(IOError, match="corruption in leaf a"):
        ckpt.read_subset(str(tmp_path), 1, ["a"])

    # truncation: dies inside the decompressor, still attributed to the leaf
    with open(a_file, "wb") as f:
        f.write(forged[: len(forged) // 2])
    with pytest.raises(IOError, match="corruption in leaf a"):
        ckpt.read_subset(str(tmp_path), 1, ["a"])

    # the untouched leaf is unaffected by its corrupt sibling
    sub = ckpt.read_subset(str(tmp_path), 1, ["b"])
    np.testing.assert_array_equal(sub["b"], tree["b"])


def test_import_tenant_validates_payload_before_mutating_state(mesh):
    from repro.query.store import SketchStore

    src = PipelineCell("src", mesh, eps=0.2, policy=EveryKSteps(1))
    src.pipeline.add_tenant("t", D, eps=0.2, policy=EveryKSteps(1))
    for b in _matrix_batches(seed=6, n_batches=2):
        src.ingest("t", b)
    tree, extra = src.store.export_tenant("t")

    dst = SketchStore()
    with pytest.raises(ValueError, match="not a sketch store export"):
        dst.import_tenant(tree, {**extra, "kind": "something-else"})
    # truncated: the manifest names a snapshot whose matrix is missing
    short = {k: v for k, v in tree.items() if k != "snap_00001"}
    with pytest.raises(ValueError, match="truncated tenant payload"):
        dst.import_tenant(short, extra)
    # manifest/leaf shape disagreement
    bad = dict(tree)
    bad["snap_00001"] = np.zeros((1, 1), np.float32)
    with pytest.raises(ValueError, match="payload mismatch"):
        dst.import_tenant(bad, extra)
    # a payload spanning multiple tenants is refused outright
    mixed = dict(extra)
    mixed["snapshots"] = [
        dict(extra["snapshots"][0]),
        {**extra["snapshots"][1], "tenant": "other"},
    ]
    with pytest.raises(ValueError, match="spans multiple tenants"):
        dst.import_tenant(tree, mixed)
    # none of the rejections left a half-imported tenant behind
    assert dst.tenants() == [] and len(dst) == 0
    # the pristine payload still imports cleanly on the same store
    assert dst.import_tenant(tree, extra) == [1, 2]
    np.testing.assert_array_equal(dst.get("t").matrix, src.store.get("t").matrix)
    # import-over-resident refuses before touching anything
    with pytest.raises(ValueError, match="already present"):
        dst.import_tenant(tree, extra)
    assert len(dst) == 2
    src.close()


# ---------------------------------------------------------------------------
# router: routing, fan-out, shed propagation, parallel ingest
# ---------------------------------------------------------------------------


def test_router_routes_by_ring_and_rejects_unknown(mesh):
    cells = [PipelineCell(f"cell-{i}", mesh, eps=0.2) for i in range(3)]
    router = ClusterRouter(cells)
    router.add_tenant("t", D, eps=0.2, policy=EveryKSteps(1))
    assert router.placement()["t"] == router.ring.place("t")
    assert router.cell_for("t").tenants() == ["t"]
    with pytest.raises(ValueError, match="already registered"):
        router.add_tenant("t", D, eps=0.2)
    with pytest.raises(KeyError, match="unknown tenant"):
        router.ingest("ghost", np.ones((1, D), np.float32))


def test_router_shed_propagates_and_is_counted_per_cell(mesh):
    cells = [PipelineCell(f"cell-{i}", mesh, eps=0.2, policy=EveryKSteps(1))
             for i in range(2)]
    router = ClusterRouter(cells)
    router.add_tenant("t", D, eps=0.2, quota=TenantQuota(max_pending=1))
    router.ingest("t", _matrix_batches(seed=5, n_batches=1)[0])
    router.submit("t", np.ones(D, np.float32))
    with pytest.raises(QueryShedError):
        router.submit("t", np.ones(D, np.float32))
    owner = router.placement()["t"]
    assert router.shed_counts()[owner] == 1
    assert sum(router.shed_counts().values()) == 1
    assert router.flush() == 1
    stats = router.stats()
    assert stats[owner]["shed"] == 1 and stats[owner]["tenants"] == 1


def test_ingest_many_parallel_matches_sequential(mesh):
    def build():
        cells = [PipelineCell(f"cell-{i}", mesh, eps=0.2, policy=EveryKSteps(1))
                 for i in range(4)]
        router = ClusterRouter(cells)
        for i in range(6):
            router.add_tenant(f"mat-{i}", D, eps=0.2, policy=EveryKSteps(1))
        return router

    batches = [
        (f"mat-{i}", b)
        for i in range(6)
        for b in _matrix_batches(seed=30 + i, n_batches=2)
    ]
    seq, par = build(), build()
    n_seq = seq.ingest_many(batches)
    n_par = par.ingest_many(batches, parallel=True)
    assert n_seq == n_par == len(batches)
    for i in range(6):
        t = f"mat-{i}"
        np.testing.assert_array_equal(
            seq.cell_for(t).store.get(t).matrix,
            par.cell_for(t).store.get(t).matrix,
        )


# ---------------------------------------------------------------------------
# serving replica: pull-based sync, read-through, staleness bounds
# ---------------------------------------------------------------------------


def test_replica_read_through_and_staleness_accounting(mesh):
    cell = PipelineCell("c", mesh, eps=0.2, policy=EveryKSteps(1))
    cell.pipeline.add_tenant("t", D, eps=0.2, policy=EveryKSteps(1))
    batches = _matrix_batches(seed=6, n_batches=4)
    for b in batches[:2]:
        cell.ingest("t", b)

    replica = ServingReplica(cell)
    x = np.ones((2, D), np.float32)
    res = replica.query_batch(x, tenant="t")  # cold: read-through then answer
    assert replica.read_throughs == 1 and replica.pulled == 2
    assert res.versions_behind == 0 and res.owner_version == 2
    np.testing.assert_array_equal(
        res.result.estimates, cell.engine.query_batch(x, tenant="t").estimates
    )

    cell.ingest("t", batches[2])  # owner moves ahead; replica serves stale
    stale = replica.query_batch(x, tenant="t")
    assert stale.versions_behind == 1 and stale.result.version == 2
    assert replica.read_throughs == 1  # no refetch: staleness is unbounded here

    assert replica.sync() == 1  # explicit pull catches up
    fresh = replica.query_batch(x, tenant="t")
    assert fresh.versions_behind == 0 and fresh.result.version == 3

    pinned = replica.query_batch(x, tenant="t", version=1)  # pulled already: local hit
    assert pinned.result.version == 1 and replica.read_throughs == 1
    assert pinned.versions_behind == 2  # staleness measured vs the owner, not local

    late = ServingReplica(cell)  # pinned miss on a cold replica read-through-fetches
    late_pinned = late.query_batch(x, tenant="t", version=2)
    assert late_pinned.result.version == 2 and late.read_throughs == 1
    stats = replica.stats()
    assert stats["tenants"] == 1 and stats["pulled"] == 3
    assert set(stats["cache"]) >= {"hits", "misses", "evictions", "hit_rate"}


def test_replica_enforces_max_versions_behind(mesh):
    cell = PipelineCell("c", mesh, eps=0.2, policy=EveryKSteps(1))
    cell.pipeline.add_tenant("t", D, eps=0.2, policy=EveryKSteps(1))
    cell.ingest("t", _matrix_batches(seed=7, n_batches=1)[0])
    replica = ServingReplica(cell, max_versions_behind=0)
    x = np.ones((1, D), np.float32)
    replica.query_batch(x, tenant="t")
    for b in _matrix_batches(seed=8, n_batches=2):
        cell.ingest("t", b)
    res = replica.query_batch(x, tenant="t")  # bound forces a refresh
    assert res.versions_behind == 0
    assert res.result.version == cell.latest_version("t") == 3
    with pytest.raises(ValueError, match="max_versions_behind"):
        ServingReplica(cell, max_versions_behind=-1)


def test_replica_follows_router_across_rebalance(mesh):
    cells = [PipelineCell(f"cell-{i}", mesh, eps=0.2, policy=EveryKSteps(1))
             for i in range(2)]
    router = ClusterRouter(cells)
    _build_mixed(router)
    replica = ServingReplica(router)
    x = np.ones((2, D), np.float32)
    before = replica.query_batch(x, tenant="mat-1")
    router.scale_to(cells + [PipelineCell("cell-2", mesh, eps=0.2,
                                          policy=EveryKSteps(1))])
    after = replica.query_batch(x, tenant="mat-1")  # owner may have moved cells
    assert after.versions_behind == 0
    np.testing.assert_array_equal(before.result.estimates, after.result.estimates)
    router.close()
