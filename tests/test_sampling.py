"""Priority sampling: unbiasedness + threshold semantics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sampling import PrioritySampler, priority_sample


def test_priority_sample_unbiased_subset_sum(rng):
    n, s = 500, 100
    w = rng.uniform(1.0, 50.0, size=n).astype(np.float32)
    total = float(w.sum())
    ests = []
    for seed in range(60):
        ps = priority_sample(jnp.asarray(w), jax.random.key(seed), s)
        ests.append(float(jnp.sum(ps.weights)))
    mean = np.mean(ests)
    # E[sum w_bar] = W (Duffield--Lund--Thorup); 60 trials, generous CI
    assert abs(mean - total) / total < 0.05, (mean, total)


def test_priority_sample_large_weights_deterministic(rng):
    n, s = 200, 50
    w = np.ones(n, np.float32)
    w[:5] = 1e6  # heavy items must always be kept
    ps = priority_sample(jnp.asarray(w), jax.random.key(1), s)
    kept = set(np.asarray(ps.indices).tolist())
    assert set(range(5)).issubset(kept)


def test_streaming_sampler_matches_oneshot_estimates(rng):
    n, s = 2000, 200
    w = rng.uniform(1.0, 20.0, size=n)
    sampler = PrioritySampler(s, np.random.default_rng(7))
    for i in range(n):
        sampler.update(i, float(w[i]))
    items, ww = sampler.sample()
    assert len(items) == s
    assert abs(ww.sum() - w.sum()) / w.sum() < 0.2
