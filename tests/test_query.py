"""Query-serving subsystem: store versioning, engine paths, cache, service.

Covers the PR acceptance gate: a 1024-direction batch served end-to-end,
with the Pallas path bit-for-bit equal to the reference under interpret
mode and every estimate inside the paper's ``eps ||A||_F^2`` envelope.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.fd import fd_init, fd_matrix, fd_update_stream
from repro.kernels.ops import quadform
from repro.kernels.ref import ref_quadform
from repro.query import QueryEngine, QueryService, SketchStore

EPS = 0.1
D = 256  # <= one quadform d-block, so the Pallas path is bit-exact vs ref


def _lowrank(rng, n, d, rank=8, noise=0.05):
    u = rng.normal(size=(n, rank)) * (np.arange(rank, 0, -1) ** 2)
    return (u @ rng.normal(size=(rank, d)) + noise * rng.normal(size=(n, d))).astype(
        np.float32
    )


@pytest.fixture(scope="module")
def published():
    """(store, A, frob, snapshot) for an FD sketch of a synthetic stream."""
    rng = np.random.default_rng(7)
    a = _lowrank(rng, 20000, D)
    l = int(np.ceil(4.0 / EPS))
    st = fd_update_stream(fd_init(l, D), jnp.asarray(a))
    frob = float(np.sum(a.astype(np.float64) ** 2))
    store = SketchStore()
    snap = store.publish(
        "run", np.asarray(fd_matrix(st)), frob=frob, eps=EPS,
        delta_sum=float(st.delta_sum), n_seen=a.shape[0],
    )
    return store, a, frob, snap


def _unit_directions(rng, n, d):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


# ---------------------------------------------------------------------------
# store
# ---------------------------------------------------------------------------


def test_store_versions_are_monotonic_immutable(rng):
    store = SketchStore()
    b = rng.normal(size=(4, 8)).astype(np.float32)
    s1 = store.publish("t", b, frob=1.0, eps=0.5)
    s2 = store.publish("t", 2 * b, frob=4.0, eps=0.5)
    s_other = store.publish("u", b, frob=1.0, eps=0.5)
    assert (s1.version, s2.version) == (1, 2)
    assert s_other.version == 1  # tenant namespaces are independent
    assert store.latest_version("t") == 2
    assert store.versions("t") == [1, 2]
    assert store.tenants() == ["t", "u"]
    # latest vs pinned
    np.testing.assert_array_equal(store.get("t").matrix, s2.matrix)
    np.testing.assert_array_equal(store.get("t", version=1).matrix, b)
    # published snapshots are frozen and decoupled from the caller's buffer
    with pytest.raises(ValueError):
        store.get("t", 1).matrix[0, 0] = 99.0
    b[0, 0] = -1.0
    assert store.get("t", 1).matrix[0, 0] != -1.0
    with pytest.raises(KeyError):
        store.get("t", version=5)
    with pytest.raises(KeyError):
        store.get("nobody")


def test_store_retention_prunes_old_versions(rng):
    store = SketchStore(retain=2)
    b = rng.normal(size=(2, 4)).astype(np.float32)
    for _ in range(5):
        store.publish("t", b, frob=1.0, eps=0.5)
    assert store.versions("t") == [4, 5]  # numbering keeps advancing
    with pytest.raises(KeyError):
        store.get("t", version=1)


def test_snapshot_error_bound_prefers_instance_bound(rng):
    store = SketchStore()
    b = rng.normal(size=(2, 4)).astype(np.float32)
    tight = store.publish("t", b, frob=100.0, eps=0.1, delta_sum=3.0)
    worst = store.publish("t", b, frob=100.0, eps=0.1)
    assert tight.error_bound == pytest.approx(3.0)
    assert worst.error_bound == pytest.approx(10.0)  # eps * ||A||_F^2


# ---------------------------------------------------------------------------
# engine: parity + paper bound + cache
# ---------------------------------------------------------------------------


def test_all_paths_agree_and_satisfy_paper_bound(published):
    store, a, frob, snap = published
    rng = np.random.default_rng(1)
    x = _unit_directions(rng, 64, D)
    truth = np.sum((a.astype(np.float64) @ x.T.astype(np.float64)) ** 2, axis=0)
    engine = QueryEngine(store)
    fp_slack = 1e-4 * frob  # f32 accumulation noise, same convention as test_fd
    results = {}
    for path in ("pallas", "cached", "naive"):
        res = engine.query_batch(x, tenant="run", path=path)
        results[path] = res.estimates
        gap = truth - res.estimates.astype(np.float64)
        # paper guarantee: 0 <= ||Ax||^2 - ||Bx||^2 <= delta_sum <= eps ||A||_F^2
        assert res.error_bound <= EPS * frob
        assert np.all(gap <= res.error_bound + fp_slack)
        assert np.all(gap >= -fp_slack)
    np.testing.assert_allclose(results["pallas"], results["cached"], rtol=1e-5)
    np.testing.assert_allclose(results["cached"], results["naive"], rtol=1e-5)


def test_engine_serves_1024_direction_batch_bitexact_vs_ref(published):
    """Acceptance gate: 1024 directions end-to-end, Pallas == ref bit-for-bit."""
    store, a, frob, snap = published
    rng = np.random.default_rng(2)
    x = _unit_directions(rng, 1024, D)
    engine = QueryEngine(store, interpret=True)
    res = engine.query_batch(x, tenant="run", path="pallas")
    want = np.asarray(ref_quadform(jnp.asarray(snap.matrix), jnp.asarray(x)))
    np.testing.assert_array_equal(res.estimates, want)
    # and the whole batch stays inside the eps envelope vs the dense truth
    truth = np.sum((a.astype(np.float64) @ x.T.astype(np.float64)) ** 2, axis=0)
    gap = truth - res.estimates.astype(np.float64)
    assert np.all(np.abs(gap) <= EPS * frob)


def test_spectrum_cache_hits_and_version_invalidation(published):
    store, a, frob, snap = published
    rng = np.random.default_rng(3)
    x = _unit_directions(rng, 8, D)
    engine = QueryEngine(store)
    engine.query_batch(x, tenant="run", path="cached")
    stats = engine.cache_stats()
    assert (stats["hits"], stats["misses"], stats["entries"]) == (0, 1, 1)
    assert stats["spectrum"] == {"hits": 0, "misses": 1, "evictions": 0}
    engine.query_batch(x, tenant="run", path="cached")
    engine.top_directions(4, tenant="run")
    engine.stable_rank(tenant="run")
    stats = engine.cache_stats()
    assert (stats["hits"], stats["misses"], stats["entries"]) == (3, 1, 1)
    assert stats["hit_rate"] == 0.75
    assert stats["factor"] == {"hits": 0, "misses": 0, "evictions": 0}
    # a new version is a new cache key: the old entry can never be served
    v2 = store.publish("run", snap.matrix * 2.0, frob=4 * frob, eps=EPS)
    res = engine.query_batch(x, tenant="run", path="cached")
    assert res.version == v2.version
    assert engine.cache_stats()["misses"] == 2
    np.testing.assert_allclose(
        res.estimates,
        4.0 * engine.query_batch(x, tenant="run", version=snap.version, path="cached").estimates,
        rtol=1e-5,
    )


def test_spectrum_cache_lru_eviction(rng):
    store = SketchStore()
    b = rng.normal(size=(4, 16)).astype(np.float32)
    for _ in range(3):
        store.publish("t", b, frob=1.0, eps=0.5)
    engine = QueryEngine(store, cache_size=2)
    for v in (1, 2, 3, 1):
        engine.spectrum("t", v)
    # v1 was evicted by v3 and had to be refactored — and the evictions
    # are *counted* (a thrashing cache must not look healthy)
    stats = engine.cache_stats()
    assert (stats["hits"], stats["misses"], stats["entries"]) == (0, 4, 2)
    assert stats["spectrum"]["evictions"] == 2
    assert stats["evictions"] == 2


def test_top_directions_match_dense_pca(published):
    store, a, frob, snap = published
    engine = QueryEngine(store)
    vt_k, s_k = engine.top_directions(2, tenant="run")
    _, _, vt = np.linalg.svd(a.astype(np.float64), full_matrices=False)
    for i in range(2):
        assert abs(float(vt_k[i] @ vt[i])) > 0.99


# ---------------------------------------------------------------------------
# kernel wrapper: ragged batches
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("l,d,n", [(17, 300, 37), (40, 256, 1000), (8, 128, 1), (3, 9, 5)])
def test_quadform_ragged_padding(l, d, n):
    rng = np.random.default_rng(l + d + n)
    b = jnp.asarray(rng.normal(size=(l, d)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    got = np.asarray(quadform(b, x))
    want = np.asarray(ref_quadform(b, x))
    assert got.shape == (n,)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4 * d)


# ---------------------------------------------------------------------------
# service: admission, coalescing, padding correctness
# ---------------------------------------------------------------------------


def test_service_coalesces_and_resolves_tickets(published):
    store, a, frob, snap = published
    rng = np.random.default_rng(4)
    x = _unit_directions(rng, 300, D)
    engine = QueryEngine(store)
    svc = QueryService(engine, tenant="run", max_batch=256, auto_flush=True)
    tickets = [svc.submit(row) for row in x]
    assert svc.pending() == 300 - 256  # one auto-flush fired at max_batch
    svc.flush()
    assert svc.pending() == 0
    want = engine.query_batch(x, tenant="run", path="pallas").estimates
    got = np.array([t.result()[0] for t in tickets], np.float32)
    np.testing.assert_allclose(got, want, rtol=1e-6)
    stats = svc.stats()
    assert stats.queries == 300 and stats.batches == 2
    # ragged tail of 44 was padded up to the 64 bucket
    assert stats.padded == 64 - 44
    assert stats.queries_per_sec > 0


def test_service_ticket_result_triggers_flush(published):
    store, a, frob, snap = published
    rng = np.random.default_rng(5)
    engine = QueryEngine(store)
    svc = QueryService(engine, tenant="run", max_batch=64, path="cached")
    x = _unit_directions(rng, 3, D)
    tickets = [svc.submit(row) for row in x]
    est, bound, version = tickets[1].result()  # implicit flush
    assert tickets[0].done and tickets[2].done
    assert version == store.latest_version("run")
    assert bound == store.get("run").error_bound
    assert est == pytest.approx(engine.query(x[1], tenant="run", path="cached"), rel=1e-6)


def test_service_rejects_bad_shapes(published):
    store, *_ = published
    svc = QueryService(QueryEngine(store), tenant="run")
    with pytest.raises(ValueError):
        svc.submit(np.zeros((2, D), np.float32))


def test_service_failed_flush_keeps_tickets_pending(published):
    store, *_ = published
    svc = QueryService(QueryEngine(store), tenant="unpublished", auto_flush=False)
    ticket = svc.submit(np.zeros(D, np.float32))
    with pytest.raises(KeyError):
        svc.flush()
    assert svc.pending() == 1 and not ticket.done
    # once the cause is fixed (tenant published), the same ticket resolves
    store.publish("unpublished", np.ones((2, D), np.float32), frob=1.0, eps=0.5)
    svc.flush()
    assert ticket.done


# ---------------------------------------------------------------------------
# tracker integration: publish() into the store
# ---------------------------------------------------------------------------


def test_tracker_publishes_versioned_snapshots(rng):
    from repro.core.tracker import DistributedMatrixTracker

    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    d = 16
    tracker = DistributedMatrixTracker(mesh, d, eps=0.25, axis="data")
    a = _lowrank(np.random.default_rng(6), 2048, d, rank=4)
    for i in range(0, 2048, 256):
        tracker.update(jnp.asarray(a[i : i + 256]))
    store = SketchStore()
    s1 = tracker.publish(store, tenant="train")
    tracker.update(jnp.asarray(a[:256]))
    s2 = tracker.publish(store, tenant="train", meta={"step": 9})
    assert (s1.version, s2.version) == (1, 2)
    assert s1.meta["protocol"] == "P2"
    assert s2.meta["step"] == 9
    assert s1.frob > 0 and s1.eps == 0.25
    # the published snapshot answers queries consistently with the tracker
    engine = QueryEngine(store)
    x = np.zeros(d, np.float32)
    x[0] = 1.0
    assert engine.query(x, tenant="train") == pytest.approx(
        tracker.query(jnp.asarray(x)), rel=1e-5, abs=1e-4
    )
