"""shard_map super-step engine: correctness vs the event-driven oracle,
communication accounting, super-step skew bound.  Multi-device tests run in
subprocesses (this process must keep exactly 1 visible device)."""

from conftest import run_multidevice

COMMON = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.distributed import ProtocolConfig, make_protocol_runner, p3_matrix
from repro.core import fd as fdlib

m, d, eps = 8, 24, 0.2
mesh = Mesh(np.array(jax.devices()).reshape(m), ("sites",))
rng = np.random.default_rng(0)
n = 4096
u = rng.normal(size=(n, 5)) * (np.arange(5,0,-1)**2)[None]
A = (u @ rng.normal(size=(5,d)) + 0.05*rng.normal(size=(n,d))).astype(np.float32)
ata = A.T@A; frob = float(np.sum(A*A))
cfg = ProtocolConfig(eps=eps, m=m, d=d, axis="sites", l_site=20, l_coord=40, s=48)
batch = 64
steps = n // (m*batch)
"""


def test_distributed_protocols_error_bounds():
    out = run_multidevice(
        COMMON
        + """
for proto in ["P1", "P2", "P3"]:
    state, step = make_protocol_runner(proto, cfg, mesh)
    for t in range(steps):
        state = step(state, jnp.asarray(A[t*m*batch:(t+1)*m*batch]))
    if proto == "P3":
        B = np.asarray(p3_matrix(state))
    else:
        B = np.asarray(fdlib.fd_matrix(state.coord_fd))
    err = np.linalg.norm(ata - B.T@B, 2)/frob
    assert err < 2*eps, (proto, err)
    c = state.comm
    assert int(c.row_msgs) > 0
    total = int(c.scalar_msgs) + int(c.row_msgs) + int(c.broadcast_events)*m
    assert total < n, (proto, total)  # beats shipping the stream
    print(proto, "err", err, "msgs", total)
print("OK")
"""
    )
    assert "OK" in out


def test_distributed_p2_comm_scales_with_eps():
    out = run_multidevice(
        COMMON
        + """
msgs = {}
for eps_i in [0.4, 0.1]:
    c2 = cfg._replace(eps=eps_i)
    state, step = make_protocol_runner("P2", c2, mesh)
    for t in range(steps):
        state = step(state, jnp.asarray(A[t*m*batch:(t+1)*m*batch]))
    msgs[eps_i] = int(state.comm.row_msgs) + int(state.comm.scalar_msgs)
assert msgs[0.1] > msgs[0.4], msgs
print("OK", msgs)
"""
    )
    assert "OK" in out


def test_distributed_matches_paper_guarantee_direction():
    """P2 coordinator estimate must UNDERestimate ||Ax||^2 (one-sided)."""
    out = run_multidevice(
        COMMON
        + """
state, step = make_protocol_runner("P2", cfg, mesh)
for t in range(steps):
    state = step(state, jnp.asarray(A[t*m*batch:(t+1)*m*batch]))
B = np.asarray(fdlib.fd_matrix(state.coord_fd))
viol = 0
for i in range(20):
    x = rng.normal(size=d); x /= np.linalg.norm(x)
    ax = float(np.sum((A@x)**2)); bx = float(np.sum((B@x)**2))
    if bx > ax * (1+1e-3): viol += 1
assert viol == 0, viol
print("OK")
"""
    )
    assert "OK" in out
