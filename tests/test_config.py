"""Layer-pattern compiler + config invariants (hypothesis-backed)."""
import numpy as np
import pytest

try:  # property tests fall back to a seeded sweep on minimal installs
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:
    hypothesis = None

from repro.configs.registry import ARCH_NAMES, SHAPES, cell_supported, get_config, reduced_config
from repro.models.config import group_pattern


def _expand(groups):
    out = []
    for kinds, repeats in groups:
        out.extend(list(kinds) * repeats)
    return tuple(out)


def test_group_pattern_roundtrip():
    """Folding into scan groups must exactly reproduce the layer sequence.

    Hypothesis-driven when installed; otherwise a seeded random sweep over
    the same check (hypothesis is an optional extra, never a skip reason).
    """
    from conftest import run_property

    def check(pattern):
        groups = group_pattern(tuple(pattern))
        assert _expand(groups) == tuple(pattern)

    kinds = ["global", "local", "rglru", "ssd"]
    rng = np.random.default_rng(0)
    run_property(
        check,
        given=lambda: {
            "pattern": st.lists(st.sampled_from(kinds), min_size=1, max_size=40)
        },
        cases=(
            {"pattern": [kinds[j] for j in rng.integers(0, 4, rng.integers(1, 41))]}
            for _ in range(200)
        ),
        max_examples=200,
    )


def test_group_pattern_folds_uniform_stacks():
    groups = group_pattern(("global",) * 94)
    assert groups == [(("global",), 94)]


def test_group_pattern_gemma3():
    pat = ("local",) * 5 + ("global",)
    groups = group_pattern(pat * 4 + ("local", "local"))
    assert _expand(groups) == pat * 4 + ("local", "local")
    assert sum(r for _, r in groups) < 26  # actually folded something


def test_all_archs_have_configs_and_param_counts():
    expected = {
        "recurrentgemma-2b": (2.0e9, 4.5e9),
        "qwen3-moe-235b-a22b": (2.0e11, 2.7e11),
        "mixtral-8x7b": (4.0e10, 5.2e10),
        "gemma3-1b": (0.7e9, 1.5e9),
        "h2o-danube-3-4b": (3.0e9, 4.5e9),
        "qwen3-0.6b": (0.4e9, 0.9e9),
        "smollm-135m": (1.0e8, 1.8e8),
        "internvl2-2b": (1.5e9, 2.5e9),
        "mamba2-370m": (2.5e8, 5.0e8),
        "musicgen-medium": (1.0e9, 2.0e9),
    }
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        n = cfg.param_count()
        lo, hi = expected[arch]
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e}, {hi:.1e}]"
        if cfg.is_moe:
            assert cfg.active_param_count() < n


def test_moe_active_params_match_a22b():
    cfg = get_config("qwen3-moe-235b-a22b")
    active = cfg.active_param_count()
    assert 1.5e10 <= active <= 3.0e10, f"A22B active params: {active:.3e}"


def test_long_500k_skips_match_design_doc():
    skip = {a for a in ARCH_NAMES if not cell_supported(get_config(a), SHAPES["long_500k"])[0]}
    assert skip == {
        "qwen3-moe-235b-a22b",
        "qwen3-0.6b",
        "smollm-135m",
        "internvl2-2b",
        "musicgen-medium",
    }


def test_reduced_configs_stay_in_family():
    for arch in ARCH_NAMES:
        full = get_config(arch)
        red = reduced_config(full)
        assert red.family == full.family
        assert red.layer_pattern == full.layer_pattern
        assert red.is_moe == full.is_moe
        assert red.param_count() < 1e7


def test_vocab_padding():
    cfg = get_config("internvl2-2b")
    assert cfg.padded_vocab % 128 == 0 and cfg.padded_vocab >= cfg.vocab_size
    assert cfg.padded_vocab % 16 == 0  # shards over the model axis
