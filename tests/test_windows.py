"""Time as a first-class dimension: windowed + decayed protocol properties.

The tentpole contract: every protocol kind (matrix, hh, quantile,
leverage) gains a sliding-window and an exponential-decay flavor built by
folding the existing merge identities over per-bucket jit states
(``core/windows.py``), registered as ordinary ``(kind, engine, name)``
specs.  This file pins the three properties the wrappers must satisfy:

  * a windowed answer equals a fresh sketch fed only the in-window rows,
    within the kind's eps envelope — for all four kinds, both engines,
    and across a ``state_payload``/``restore_payload`` round trip;
  * arrival order does not matter: timestamp-shuffled ingest within the
    lateness bound is byte-identical to the sorted run (bucket-merge
    order invariance), and late-beyond-watermark rows are shed with a
    counted typed error, never silently dropped or applied;
  * exponential decay matches the closed-form ``gamma^(T - t)`` weights
    against a float64 reference to 1e-5.

Property tests run under hypothesis when installed and as seeded sweeps
otherwise (``conftest.run_property``) — never skipped.
"""
import jax
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
except ModuleNotFoundError:  # tier-1 runs on minimal installs too
    st = None

from conftest import run_property
from repro.core.windows import LateRowError, TimedRows, WatermarkTracker
from repro.runtime.policies import EveryKSteps, OnWindowClose
from repro.runtime.pipeline import StreamingPipeline
from repro.runtime.registry import create_protocol, specs

KINDS = ("matrix", "hh", "quantile", "leverage")
D = 8
EPS = 0.25
WINDOW, BUCKETS = 16.0, 4  # bucket width 4.0
M = 4  # paper sites for the event engine


@pytest.fixture(scope="module", autouse=True)
def _fresh_compile_cache():
    """This module runs last in a full tier-1 sweep, after ~400 tests'
    compiled executables have piled up in-process; XLA's single-core JIT
    has been seen segfaulting on the next compile under that load.
    Dropping the cache here costs a few recompiles and buys stability."""
    jax.clear_caches()


@pytest.fixture(scope="module")
def mesh():
    return jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))


def _make(kind, engine, mode, mesh=None, **kw):
    """One windowed/decayed protocol through the public registry path."""
    name = ("P2" if kind == "matrix" else "P1") + mode
    base = dict(eps=EPS)
    if kind in ("matrix", "leverage"):
        base["d"] = D
    if engine == "shard":
        base["mesh"] = mesh
    else:
        base["m"] = M
        base.setdefault("sites", 2)  # exercise the per-site round-robin
    base.update(kw)
    return create_protocol(name, engine=engine, kind=kind, **base)


def _batch(kind, rng, n=8):
    if kind in ("matrix", "leverage"):
        return rng.normal(size=(n, D)).astype(np.float32)
    if kind == "hh":
        return np.stack(
            [rng.integers(0, 32, n), rng.uniform(0.5, 2.0, n)], axis=1
        ).astype(np.float64)
    return np.stack(
        [rng.normal(size=n) * 5.0, np.ones(n)], axis=1
    ).astype(np.float64)


def _seeds(n):
    return [{"seed": s} for s in range(n)]


def _given_seed():
    return {"seed": st.integers(0, 2**16)}


def test_all_sixteen_windowed_specs_are_registered():
    """(4 kinds) x (win, decay) x (event, shard) land in the registry."""
    found = {
        (s.kind, s.engine, s.name)
        for s in specs()
        if s.name.endswith(("win", "decay"))
    }
    want = {
        (kind, engine, ("P2" if kind == "matrix" else "P1") + suffix)
        for kind in KINDS
        for engine in ("event", "shard")
        for suffix in ("win", "decay")
    }
    assert want <= found


# ---------------------------------------------------------------------------
# Property 1: windowed answer == fresh sketch over in-window rows (eps env.)
# ---------------------------------------------------------------------------


def _envelope_check(kind, proto, kept_rows, rng):
    """Served answer vs the exact in-window stream, per-kind eps envelope."""
    if kind == "matrix":
        frob = float(np.sum(kept_rows.astype(np.float64) ** 2))
        x = rng.normal(size=D)
        x = (x / np.linalg.norm(x)).astype(np.float32)
        exact = float(np.sum((kept_rows.astype(np.float64) @ x) ** 2))
        est = float(proto.query(x))
        slack = 1e-3 * frob + 1e-4
        assert exact - est >= -slack
        assert exact - est <= EPS * frob + slack
        assert proto.frob_estimate() == pytest.approx(frob, rel=1e-4)
    elif kind == "hh":
        w_tot = float(kept_rows[:, 1].sum())
        exact = {}
        for key, w in kept_rows:
            exact[int(key)] = exact.get(int(key), 0.0) + float(w)
        est = proto.estimates()
        assert proto.total_weight() == pytest.approx(w_tot, rel=1e-5)
        for key in set(exact) | set(est):
            err = abs(est.get(key, 0.0) - exact.get(key, 0.0))
            assert err <= EPS * w_tot + 1e-6
    elif kind == "quantile":
        w_tot = float(kept_rows[:, 1].sum())
        assert proto.total_weight() == pytest.approx(w_tot, rel=1e-5)
        probes = np.quantile(kept_rows[:, 0], [0.1, 0.5, 0.9])
        exact = np.array(
            [kept_rows[kept_rows[:, 0] <= v, 1].sum() for v in probes]
        )
        est = proto.rank(probes)
        assert np.all(np.abs(est - exact) <= EPS * w_tot + 1e-6)
    else:  # leverage
        frob = float(np.sum(kept_rows.astype(np.float64) ** 2))
        x = rng.normal(size=D)
        x = x / np.linalg.norm(x)
        exact = float(np.sum((kept_rows.astype(np.float64) @ x) ** 2))
        tab = proto.sampled_rows().astype(np.float64)
        rows, weights = tab[:, :D], tab[:, D + 1]
        est = float(np.sum(weights * (rows @ x) ** 2))
        slack = 1e-3 * frob + 1e-4
        assert exact - est >= -slack  # never overcounts mass
        assert exact - est <= 1.5 * EPS * frob + slack
        assert proto.total_weight() == pytest.approx(frob, rel=1e-4)


@pytest.mark.parametrize("engine", ("event", "shard"))
@pytest.mark.parametrize("kind", KINDS)
def test_windowed_answer_matches_fresh_inwindow_sketch(kind, engine, mesh):
    """Sliding window == fresh sketch fed only in-window rows, within the
    kind's eps envelope — including a checkpoint round trip mid-stream.

    The stream uses integer timestamps aligned to the bucket grid, so the
    retained-bucket set is exactly ``ts >= watermark - WINDOW`` and the
    reference stream is unambiguous.
    """

    def check(seed):
        rng = np.random.default_rng(seed)
        # T chosen so (T-1) - WINDOW lands on a bucket edge: retained rows
        # are exactly those with ts >= T-1-WINDOW.
        total = 29
        batches = [(float(t), _batch(kind, rng)) for t in range(total)]
        proto = _make(kind, engine, "win", mesh=mesh,
                      window=WINDOW, buckets=BUCKETS)
        for i, (ts, rows) in enumerate(batches):
            proto.step(rows, ts=ts)
            if i == total // 2:
                # checkpoint round trip mid-stream: the restored protocol
                # must continue (and answer) bit-identically
                arrays, meta = proto.state_payload()
                restored = _make(kind, engine, "win", mesh=mesh,
                                 window=WINDOW, buckets=BUCKETS)
                restored.restore_payload(arrays, meta)
                proto = restored
        cutoff = (total - 1) - WINDOW
        kept = np.concatenate(
            [rows for ts, rows in batches if ts >= cutoff]
        ).astype(np.float64)
        _envelope_check(kind, proto, kept, rng)
        # the window actually dropped something (the property isn't vacuous)
        assert proto.rows_seen > kept.shape[0]

    run_property(
        check,
        given=None if st is None else _given_seed,
        cases=_seeds(3),
        max_examples=10,
    )


@pytest.mark.parametrize("engine", ("event", "shard"))
@pytest.mark.parametrize("kind", KINDS)
def test_checkpoint_round_trip_is_bit_identical(kind, engine, mesh):
    """state_payload -> restore_payload reproduces arrays, counters, and
    subsequent answers bit-for-bit, pending out-of-order batches included."""
    rng = np.random.default_rng(11)
    proto = _make(kind, engine, "win", mesh=mesh,
                  window=WINDOW, buckets=BUCKETS, lateness=6.0)
    for ts in (0.0, 1.0, 4.0, 3.0, 9.0, 7.0):  # leaves batches pending
        proto.step(_batch(kind, rng), ts=ts)
    arrays, meta = proto.state_payload()
    restored = _make(kind, engine, "win", mesh=mesh,
                     window=WINDOW, buckets=BUCKETS, lateness=6.0)
    restored.restore_payload(arrays, meta)
    a2, m2 = restored.state_payload()
    assert meta == m2
    assert sorted(arrays) == sorted(a2)
    for k in arrays:
        np.testing.assert_array_equal(np.asarray(arrays[k]), np.asarray(a2[k]))
    # continues identically: same late shed, same drained state
    tail = _batch(kind, rng)
    for p in (proto, restored):
        p.step(tail, ts=20.0)
        p.advance(40.0)
    for (k, v), (k2, v2) in zip(
        sorted(proto.state_payload()[0].items()),
        sorted(restored.state_payload()[0].items()),
    ):
        assert k == k2
        np.testing.assert_array_equal(np.asarray(v), np.asarray(v2))
    # config mismatch is rejected, not silently absorbed
    other = _make(kind, engine, "win", mesh=mesh, window=WINDOW, buckets=2)
    with pytest.raises(ValueError, match="mismatch"):
        other.restore_payload(arrays, meta)


# ---------------------------------------------------------------------------
# Property 2: arrival order / watermark semantics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", KINDS)
def test_shuffled_arrival_within_lateness_is_byte_identical(kind):
    """Batches with distinct timestamps drain in event-time order: any
    arrival shuffle inside the lateness bound yields byte-identical state
    and answers to the sorted run."""

    def check(seed):
        rng = np.random.default_rng(seed)
        batches = [(float(t), _batch(kind, rng)) for t in range(20)]
        a = _make(kind, "event", "win", window=8.0, buckets=4, lateness=100.0)
        b = _make(kind, "event", "win", window=8.0, buckets=4, lateness=100.0)
        for ts, rows in batches:
            a.step(rows, ts=ts)
        for i in rng.permutation(len(batches)):
            ts, rows = batches[i]
            b.step(rows, ts=ts)
        for p in (a, b):
            p.advance(200.0)  # watermark passes every batch: full drain
        (arr_a, meta_a), (arr_b, meta_b) = a.state_payload(), b.state_payload()
        # `closed` counts boundary crossings *observed since construction* —
        # a publish-cadence counter, order-dependent by design.  Everything
        # that describes sketch content must be identical.
        meta_a.pop("closed"), meta_b.pop("closed")
        assert meta_a == meta_b
        assert sorted(arr_a) == sorted(arr_b)
        for k in arr_a:
            np.testing.assert_array_equal(np.asarray(arr_a[k]), np.asarray(arr_b[k]))

    run_property(
        check,
        given=None if st is None else _given_seed,
        cases=_seeds(3),
        max_examples=10,
    )


@pytest.mark.parametrize("kind", KINDS)
def test_late_rows_are_shed_counted_and_never_applied(kind):
    """A batch older than the watermark raises ``LateRowError`` carrying
    the row count, increments the shed counters, and leaves state as if
    the batch never arrived — shed-and-report, not silent drop."""
    rng = np.random.default_rng(5)
    proto = _make(kind, "event", "win", window=8.0, buckets=4, lateness=2.0)
    for ts in (0.0, 5.0, 10.0):
        proto.step(_batch(kind, rng), ts=ts)
    before, meta_before = proto.state_payload()
    late = _batch(kind, rng)
    with pytest.raises(LateRowError) as err:
        proto.step(late, ts=3.0)  # watermark is 10 - 2 = 8
    assert err.value.n_rows == late.shape[0]
    assert err.value.watermark == pytest.approx(8.0)
    assert proto.late_batches == 1
    assert proto.late_rows == late.shape[0]
    after, meta_after = proto.state_payload()
    for k in before:
        np.testing.assert_array_equal(np.asarray(before[k]), np.asarray(after[k]))
    assert meta_after["applied_batches"] == meta_before["applied_batches"]
    # rows at exactly the watermark are NOT late (strict inequality)
    proto.step(_batch(kind, rng), ts=8.0)
    assert proto.late_batches == 1


def test_watermark_tracker_semantics():
    """watermark = max event time - lateness; lateness is strict."""
    wm = WatermarkTracker(lateness=3.0)
    assert wm.watermark == float("-inf")
    wm.observe(10.0)
    assert wm.watermark == 7.0
    wm.observe(5.0)  # max_ts is monotone
    assert wm.watermark == 7.0
    assert wm.is_late(6.9) and not wm.is_late(7.0)
    with pytest.raises(ValueError):
        WatermarkTracker(lateness=-1.0)


# ---------------------------------------------------------------------------
# Property 3: exponential decay matches the closed-form weights
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", KINDS)
def test_decay_matches_closed_form_reference(kind):
    """With capacities large enough that no shrink fires, every decayed
    answer equals the float64 closed form ``sum_t gamma^(T-t) f(rows_t)``
    to 1e-5 relative."""

    def check(seed):
        rng = np.random.default_rng(seed)
        gamma, total = 0.9, 12
        big = {"matrix": {"l": 128}, "hh": {"k": 128},
               "quantile": {}, "leverage": {"cap": 256}}[kind]
        proto = _make(kind, "event", "decay", gamma=gamma, sites=1, **big)
        batches = [(float(t), _batch(kind, rng)) for t in range(total)]
        for ts, rows in batches:
            proto.step(rows, ts=ts)
        t_ref = batches[-1][0]
        w = {ts: gamma ** (t_ref - ts) for ts, _ in batches}
        if kind == "matrix":
            x = rng.normal(size=D)
            x = x / np.linalg.norm(x)
            want_q = sum(
                w[ts] * float(np.sum((rows.astype(np.float64) @ x) ** 2))
                for ts, rows in batches
            )
            want_f = sum(
                w[ts] * float(np.sum(rows.astype(np.float64) ** 2))
                for ts, rows in batches
            )
            assert float(proto.query(x.astype(np.float32))) == pytest.approx(
                want_q, rel=1e-5
            )
            assert proto.frob_estimate() == pytest.approx(want_f, rel=1e-5)
        elif kind == "hh":
            want = {}
            for ts, rows in batches:
                for key, wt in rows:
                    want[int(key)] = want.get(int(key), 0.0) + w[ts] * float(wt)
            est = proto.estimates()
            for key, val in want.items():
                assert est.get(key, 0.0) == pytest.approx(val, rel=1e-5)
            assert proto.total_weight() == pytest.approx(
                sum(want.values()), rel=1e-5
            )
        elif kind == "quantile":
            want = sum(w[ts] * float(rows[:, 1].sum()) for ts, rows in batches)
            assert proto.total_weight() == pytest.approx(want, rel=1e-5)
        else:  # leverage
            want_m = sum(
                w[ts] * float(np.sum(rows.astype(np.float64) ** 2))
                for ts, rows in batches
            )
            assert proto.total_weight() == pytest.approx(want_m, rel=1e-5)
            x = rng.normal(size=D)
            x = x / np.linalg.norm(x)
            want_q = sum(
                w[ts] * float(np.sum((rows.astype(np.float64) @ x) ** 2))
                for ts, rows in batches
            )
            tab = proto.sampled_rows().astype(np.float64)
            est = float(np.sum(tab[:, D + 1] * (tab[:, :D] @ x) ** 2))
            assert est == pytest.approx(want_q, rel=1e-5)

    run_property(
        check,
        given=None if st is None else _given_seed,
        cases=_seeds(3),
        max_examples=10,
    )


def test_decay_half_life_parameterization():
    """half_life is sugar for gamma = 2**(-1/half_life): mass halves."""
    rng = np.random.default_rng(3)
    proto = _make("quantile", "event", "decay", half_life=4.0, sites=1)
    rows = np.stack([rng.normal(size=16), np.ones(16)], 1)
    proto.step(rows, ts=0.0)
    w0 = proto.total_weight()
    proto.advance(4.0)
    proto.step(rows[:0], ts=4.0)  # empty batch: pure time advance
    # decay applies on the next real insert; force it with a tiny batch
    proto.step(np.array([[0.0, 0.0]]), ts=4.0)
    assert proto.total_weight() == pytest.approx(w0 / 2.0, rel=1e-5)
    with pytest.raises(ValueError):
        _make("quantile", "event", "decay", gamma=0.9, half_life=4.0)


# ---------------------------------------------------------------------------
# Runtime integration: OnWindowClose, published_at, gauges, packed serving
# ---------------------------------------------------------------------------


def test_pipeline_on_window_close_publishes_per_bucket_edge(mesh):
    """OnWindowClose fires exactly when a bucket boundary passes the
    watermark; published_at rides the event-time watermark and as_of
    time-travels to the version live at that instant."""
    rng = np.random.default_rng(0)
    pipe = StreamingPipeline(mesh, eps=EPS)
    pipe.add_windowed_tenant(
        "w", kind="matrix", d=D, window=8.0, buckets=4, policy=OnWindowClose()
    )
    published = []
    for t in range(20):
        snap = pipe.ingest("w", _batch("matrix", rng), ts=float(t))
        if snap is not None:
            published.append((float(t), snap))
    proto = pipe.tracker("w")
    assert len(published) == proto.windows_closed() > 0
    for ts, snap in published:
        assert snap.published_at == ts  # the watermark at publish time
        assert snap.meta["workload"] == "matrix"  # rides the matrix sweeps
        assert snap.meta["windowed"] is True
        assert pipe.store.as_of("w", snap.published_at).version == snap.version
    # between two edges, as_of pins the older version
    (t0, s0), (t1, s1) = published[0], published[1]
    assert pipe.store.as_of("w", (t0 + t1) / 2.0).version == s0.version
    # windowed snapshots serve through the ordinary packed sweep
    x = np.ones(D, np.float32) / np.sqrt(D)
    ticket = pipe.submit("w", x)
    pipe.flush()
    assert ticket.version == published[-1][1].version
    want = float(np.sum((pipe.store.get("w").matrix.astype(np.float64) @ x) ** 2))
    assert ticket.estimate == pytest.approx(want, rel=1e-4)
    pipe.close()


def test_pipeline_sheds_late_rows_with_counter(mesh):
    """Pipeline-level shed path: LateRowError propagates AND the shared
    late_rows ingest counter accounts for every shed row."""
    rng = np.random.default_rng(1)
    pipe = StreamingPipeline(mesh, eps=EPS)
    pipe.add_windowed_tenant(
        "w", kind="hh", window=8.0, buckets=4, lateness=1.0,
        policy=EveryKSteps(1),
    )
    pipe.ingest("w", _batch("hh", rng), ts=10.0)
    late = _batch("hh", rng)
    with pytest.raises(LateRowError):
        pipe.ingest("w", late, ts=2.0)
    assert pipe.stats()["late_rows"] == late.shape[0]
    # gauge exists for windowed tenants and tracks event-time lag
    payload = pipe.obs.registry.to_json()
    assert "repro_tenant_window_lag" in payload
    # TimedRows and ts= are the same wire format
    pipe.ingest("w", TimedRows(_batch("hh", rng), 11.0))
    assert pipe.tracker("w").watermark() == 10.0
    pipe.close()


def test_ingest_many_threads_event_time_serially(mesh):
    """(tenant, rows, ts) triples take the serial path and land in the
    same state as one-by-one timed ingest; late batches in a wave are
    counted-and-skipped, not wave-aborting."""
    rng = np.random.default_rng(2)
    mk = lambda: _batch("quantile", rng)
    batches = [(float(t), mk()) for t in range(8)]
    a = StreamingPipeline(mesh, eps=EPS)
    b = StreamingPipeline(mesh, eps=EPS)
    for pipe in (a, b):
        pipe.add_windowed_tenant(
            "q", kind="quantile", window=100.0, policy=EveryKSteps(1)
        )
    for ts, rows in batches:
        a.ingest("q", rows, ts=ts)
    b.ingest_many([("q", rows, ts) for ts, rows in batches])
    (arr_a, meta_a) = a._tenants["q"].adapter.state_payload()
    (arr_b, meta_b) = b._tenants["q"].adapter.state_payload()
    assert meta_a == meta_b
    for k in arr_a:
        np.testing.assert_array_equal(np.asarray(arr_a[k]), np.asarray(arr_b[k]))
    # a late batch inside a wave is shed (counted) while the wave proceeds
    before = b.stats()["late_rows"]
    late = mk()
    n = b.ingest_many([("q", late, 0.0), ("q", mk(), 9.0)])
    assert b.stats()["late_rows"] == before + late.shape[0]
    assert n >= 1  # the in-time batch still published
    a.close(), b.close()
