"""Weighted Misra--Gries / SpaceSaving bounds, mergeability, and codecs.

The mg_merge algebra tests pin down the invariants the runtime's shard HH
engine leans on: the coordinator folds shipped site summaries with
``mg_merge`` in site order, so the merge must be commutative (estimates
don't depend on gather order) and associativity-robust (any merge tree
stays inside the summed error budget), with the empty summary as identity
(masked non-senders contribute nothing).
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property-based tests skip gracefully on minimal installs
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:
    hypothesis = None

from repro.core.hh import (
    MGSketch,
    SpaceSaving,
    decode_hh_snapshot,
    encode_hh_snapshot,
    exact_heavy_hitters,
    mg_estimate,
    mg_init,
    mg_items,
    mg_merge,
    mg_update_stream,
)


def _stream(rng, n=20000, universe=2000, beta=100.0, skew=2.0):
    keys = (rng.zipf(skew, size=n) % universe).astype(np.int64)
    w = rng.uniform(1.0, beta, size=n)
    return keys, w


def test_mg_dict_bound(rng):
    keys, w = _stream(rng)
    k = 100
    mg = MGSketch(k)
    mg.extend(keys, w)
    _, totals, W = exact_heavy_hitters(keys, w, 0.01)
    for e, true in totals.items():
        est = mg.estimate(e)
        assert est <= true + 1e-6  # MG underestimates
        assert true - est <= W / (k + 1) + 1e-6


def test_spacesaving_bound(rng):
    keys, w = _stream(rng)
    k = 100
    ss = SpaceSaving(k)
    for kk, ww in zip(keys.tolist(), w.tolist()):
        ss.update(kk, ww)
    _, totals, W = exact_heavy_hitters(keys, w, 0.01)
    for e, true in totals.items():
        est = ss.estimate(e)
        if est > 0:
            assert est >= true - 1e-6  # SS overestimates
            assert est - true <= W / k + 1e-6


def test_mg_jax_matches_dict(rng):
    keys, w = _stream(rng, n=3000, universe=300)
    k = 64
    mg = MGSketch(k)
    mg.extend(keys, w)
    st_ = mg_update_stream(mg_init(k), jnp.asarray(keys), jnp.asarray(w))
    for e in list(mg.counters)[:30]:
        np.testing.assert_allclose(
            float(mg_estimate(st_, jnp.int32(e))), mg.estimate(e), rtol=1e-4, atol=1e-2
        )


def test_mg_merge_bound(rng):
    keys, w = _stream(rng, n=4000, universe=300)
    k = 64
    half = len(keys) // 2
    s1 = mg_update_stream(mg_init(k), jnp.asarray(keys[:half]), jnp.asarray(w[:half]))
    s2 = mg_update_stream(mg_init(k), jnp.asarray(keys[half:]), jnp.asarray(w[half:]))
    merged = mg_merge(s1, s2)
    _, totals, W = exact_heavy_hitters(keys, w, 0.01)
    for e, true in list(totals.items())[:50]:
        est = float(mg_estimate(merged, jnp.int32(e)))
        assert est <= true + 1e-2
        assert true - est <= 2 * W / (k + 1) + 1e-2  # merged error adds


def _third_streams(rng, k=48):
    """Three disjoint MGState summaries over thirds of one stream."""
    keys, w = _stream(rng, n=3000, universe=200)
    parts = []
    for i in range(3):
        lo, hi = i * 1000, (i + 1) * 1000
        parts.append(
            mg_update_stream(mg_init(k), jnp.asarray(keys[lo:hi]), jnp.asarray(w[lo:hi]))
        )
    return keys, w, parts, k


def test_mg_merge_commutative(rng):
    """Gather order must not matter: mg_merge(a, b) == mg_merge(b, a) as an
    estimate map (the shard engine folds sites in arbitrary mesh order)."""
    keys, _, (s1, s2, _), _ = _third_streams(rng)
    ab, ba = mg_merge(s1, s2), mg_merge(s2, s1)
    assert mg_items(ab) == pytest.approx(mg_items(ba), rel=1e-5)
    np.testing.assert_allclose(float(ab.weight), float(ba.weight), rtol=1e-6)
    np.testing.assert_allclose(float(ab.shrink), float(ba.shrink), rtol=1e-6)


def test_mg_merge_associativity_error_budget(rng):
    """Any merge tree over the same summaries stays inside the summed
    W/(k+1) budget, and both association orders agree on total weight."""
    keys, w, (s1, s2, s3), k = _third_streams(rng)
    left = mg_merge(mg_merge(s1, s2), s3)
    right = mg_merge(s1, mg_merge(s2, s3))
    np.testing.assert_allclose(float(left.weight), float(right.weight), rtol=1e-6)
    _, totals, W = exact_heavy_hitters(keys, w, 0.01)
    # merge depth 2 on top of 3 base summaries: <= 3 error terms of W/(k+1)
    budget = 3.0 * W / (k + 1) + 1e-2
    for merged in (left, right):
        items = mg_items(merged)
        for e, true in totals.items():
            est = items.get(e, 0.0)
            assert est <= true + 1e-2
            assert true - est <= budget
        # the shrink witness certifies the instance error
        assert float(merged.shrink) <= budget


def test_mg_merge_empty_identity(rng):
    """The empty summary is mg_merge's identity — what makes the shard
    engine's masked (non-sending) gather lanes correct."""
    keys, _, (s1, _, _), k = _third_streams(rng)
    for merged in (mg_merge(s1, mg_init(k)), mg_merge(mg_init(k), s1)):
        assert mg_items(merged) == pytest.approx(mg_items(s1), rel=1e-6)
        np.testing.assert_allclose(float(merged.weight), float(s1.weight))
        np.testing.assert_allclose(float(merged.shrink), float(s1.shrink))


def test_spacesaving_recall(rng):
    """SpaceSaving overestimates, so thresholding at phi*W misses no true
    heavy hitter (the guarantee P2/P4 use it for)."""
    keys, w = _stream(rng)
    ss = SpaceSaving(200)
    for kk, ww in zip(keys.tolist(), w.tolist()):
        ss.update(kk, ww)
    hh, totals, W = exact_heavy_hitters(keys, w, 0.02)
    returned = {e for e, v in ss.items().items() if v >= 0.02 * W}
    assert set(hh).issubset(returned)


def test_sketch_state_dict_round_trip(rng):
    """MGSketch/SpaceSaving state dicts rebuild bit-identical sketches."""
    keys, w = _stream(rng, n=5000, universe=300)
    mg, ss = MGSketch(64), SpaceSaving(64)
    for kk, ww in zip(keys.tolist(), w.tolist()):
        mg.update(kk, ww)
        ss.update(kk, ww)
    mg2 = MGSketch.from_state(mg.state_dict())
    ss2 = SpaceSaving.from_state(ss.state_dict())
    assert (mg2.counters, mg2.weight, mg2.shrink) == (mg.counters, mg.weight, mg.shrink)
    assert (ss2.counters, ss2.weight) == (ss.counters, ss.weight)
    # and they continue identically
    for kk, ww in zip(keys.tolist()[:500], w.tolist()[:500]):
        mg.update(kk, ww)
        mg2.update(kk, ww)
    assert mg2.counters == mg.counters


def test_hh_snapshot_codec_round_trip(rng):
    """encode/decode invert each other; encoding is canonical (sorted)."""
    est = {17: 3.5, 2: 1.25, 40001: 7.0}
    mat = encode_hh_snapshot(est)
    assert mat.shape == (3, 2) and mat.dtype == np.float32
    assert list(mat[:, 0]) == sorted(est)  # canonical order
    assert decode_hh_snapshot(mat) == est
    assert encode_hh_snapshot({}).shape == (0, 2)
    assert decode_hh_snapshot(np.zeros((0, 2), np.float32)) == {}
    with pytest.raises(ValueError):
        encode_hh_snapshot({1 << 24: 1.0})  # would not survive f32
    with pytest.raises(ValueError):
        decode_hh_snapshot(np.zeros((2, 3), np.float32))


def test_mg_property():
    """MG estimate error stays within W/(k+1) for arbitrary weighted streams.

    Hypothesis when installed, else a seeded sweep over the same check.
    """
    from conftest import run_property

    def check(data, k):
        mg = MGSketch(k)
        totals: dict[int, float] = {}
        W = 0.0
        for e, w in data:
            mg.update(e, w)
            totals[e] = totals.get(e, 0.0) + w
            W += w
        for e, true in totals.items():
            est = mg.estimate(e)
            assert est <= true + 1e-6
            assert true - est <= W / (k + 1) + 1e-6

    rng = np.random.default_rng(0)

    def seeded():
        for _ in range(40):
            n = int(rng.integers(10, 301))
            yield {
                "data": list(
                    zip(
                        rng.integers(0, 31, n).tolist(),
                        rng.uniform(1.0, 50.0, n).tolist(),
                    )
                ),
                "k": int(rng.integers(4, 33)),
            }

    run_property(
        check,
        given=lambda: {
            "data": st.lists(
                st.tuples(st.integers(0, 30), st.floats(1.0, 50.0)),
                min_size=10,
                max_size=300,
            ),
            "k": st.integers(4, 32),
        },
        cases=seeded(),
        max_examples=40,
    )
