"""Weighted Misra--Gries / SpaceSaving bounds + mergeability."""
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property-based tests skip gracefully on minimal installs
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:
    hypothesis = None

from repro.core.hh import (
    MGSketch,
    SpaceSaving,
    exact_heavy_hitters,
    mg_estimate,
    mg_init,
    mg_merge,
    mg_update_stream,
)


def _stream(rng, n=20000, universe=2000, beta=100.0, skew=2.0):
    keys = (rng.zipf(skew, size=n) % universe).astype(np.int64)
    w = rng.uniform(1.0, beta, size=n)
    return keys, w


def test_mg_dict_bound(rng):
    keys, w = _stream(rng)
    k = 100
    mg = MGSketch(k)
    mg.extend(keys, w)
    _, totals, W = exact_heavy_hitters(keys, w, 0.01)
    for e, true in totals.items():
        est = mg.estimate(e)
        assert est <= true + 1e-6  # MG underestimates
        assert true - est <= W / (k + 1) + 1e-6


def test_spacesaving_bound(rng):
    keys, w = _stream(rng)
    k = 100
    ss = SpaceSaving(k)
    for kk, ww in zip(keys.tolist(), w.tolist()):
        ss.update(kk, ww)
    _, totals, W = exact_heavy_hitters(keys, w, 0.01)
    for e, true in totals.items():
        est = ss.estimate(e)
        if est > 0:
            assert est >= true - 1e-6  # SS overestimates
            assert est - true <= W / k + 1e-6


def test_mg_jax_matches_dict(rng):
    keys, w = _stream(rng, n=3000, universe=300)
    k = 64
    mg = MGSketch(k)
    mg.extend(keys, w)
    st_ = mg_update_stream(mg_init(k), jnp.asarray(keys), jnp.asarray(w))
    for e in list(mg.counters)[:30]:
        np.testing.assert_allclose(
            float(mg_estimate(st_, jnp.int32(e))), mg.estimate(e), rtol=1e-4, atol=1e-2
        )


def test_mg_merge_bound(rng):
    keys, w = _stream(rng, n=4000, universe=300)
    k = 64
    half = len(keys) // 2
    s1 = mg_update_stream(mg_init(k), jnp.asarray(keys[:half]), jnp.asarray(w[:half]))
    s2 = mg_update_stream(mg_init(k), jnp.asarray(keys[half:]), jnp.asarray(w[half:]))
    merged = mg_merge(s1, s2)
    _, totals, W = exact_heavy_hitters(keys, w, 0.01)
    for e, true in list(totals.items())[:50]:
        est = float(mg_estimate(merged, jnp.int32(e)))
        assert est <= true + 1e-2
        assert true - est <= 2 * W / (k + 1) + 1e-2  # merged error adds


def test_mg_property():
    pytest.importorskip("hypothesis")

    @hypothesis.given(
        data=st.lists(
            st.tuples(st.integers(0, 30), st.floats(1.0, 50.0)), min_size=10, max_size=300
        ),
        k=st.integers(4, 32),
    )
    @hypothesis.settings(max_examples=40, deadline=None)
    def check(data, k):
        mg = MGSketch(k)
        totals: dict[int, float] = {}
        W = 0.0
        for e, w in data:
            mg.update(e, w)
            totals[e] = totals.get(e, 0.0) + w
            W += w
        for e, true in totals.items():
            est = mg.estimate(e)
            assert est <= true + 1e-6
            assert true - est <= W / (k + 1) + 1e-6

    check()
