"""Packed multi-tenant ingest == serial ingest, on served answers.

The stacked super-step (``runtime.ingest_packed`` over
``dist.make_packed_runner``) must be invisible to everything downstream:
same publishes, same served answers (to fp tolerance; eigh rotation
freedom means raw buffers may differ), same checkpoint round-trips —
including mid-pack, while members' states still live inside the resident
stacked pack.  Multi-site coverage runs out of process (the in-process
suite must keep exactly one visible device); the single-device mesh
covers the unit seams in process.
"""
import numpy as np
import pytest

from conftest import run_multidevice


def _mesh():
    import jax

    return jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))


@pytest.fixture
def mesh():
    return _mesh()


def _fleet(mesh, policy=None):
    from repro.runtime import EveryKSteps, StreamingPipeline

    pipe = StreamingPipeline(mesh, eps=0.2, policy=policy or EveryKSteps(2))
    for i, _n in enumerate((32, 16, 8)):
        pipe.add_tenant(f"m{i}", 32, protocol="P2")
    return pipe


def _waves(rng, waves=4, cold=None):
    sizes = {"m0": 32, "m1": 16, "m2": 8}
    batches = []
    for w in range(waves):
        for name, n in sizes.items():
            if name == cold and w == 0:
                continue  # cold tenant joins the pack a wave late
            batches.append((name, rng.normal(size=(n, 32)).astype(np.float32)))
    return batches


def test_packed_matches_serial_ragged_cold(mesh, rng):
    """Ragged batch sizes + a cold tenant: identical served answers."""
    from repro.runtime import StreamingPipeline  # noqa: F401  (import check)

    pa, pb = _fleet(mesh), _fleet(mesh)
    batches = _waves(rng, cold="m1")
    na = pa.ingest_many(batches, packed=True)
    nb = pb.ingest_many(batches, packed=False)
    assert na == nb
    sa = pa.stats()
    assert sa["packed_launches"] > 0
    assert sa["restacks"] <= sa["packed_launches"]
    assert pb.stats()["packed_launches"] == 0
    xs = rng.normal(size=(5, 32)).astype(np.float32)
    for name in ("m0", "m1", "m2"):
        for x in xs:
            ta, tb = pa.submit(name, x), pb.submit(name, x)
            pa.flush()
            pb.flush()
            np.testing.assert_allclose(
                ta.result()[0], tb.result()[0], rtol=1e-5, atol=1e-5
            )
    pa.close()
    pb.close()


def test_resident_stack_reused_and_invalidated(mesh, rng):
    """Steady waves reuse the stacked state; a serial step forces a restack."""
    pipe = _fleet(mesh)
    for _ in range(3):
        pipe.ingest_many(_waves(rng, waves=1), packed=True)
    s = pipe.stats()
    assert s["packed_launches"] == 3
    assert s["restacks"] == 1  # only the first wave stacked member states
    # an out-of-band serial step bumps that tenant's epoch ...
    pipe.ingest("m0", rng.normal(size=(16, 32)).astype(np.float32))
    pipe.ingest_many(_waves(rng, waves=1), packed=True)
    s = pipe.stats()
    assert s["restacks"] == 2  # ... so the next packed wave restacks
    pipe.ingest_many(_waves(rng, waves=1), packed=True)
    assert pipe.stats()["restacks"] == 2  # and the wave after is resident again
    pipe.close()


def test_mid_pack_save_load_round_trip(mesh, rng, tmp_path):
    """Checkpointing while states live in the pack slot loses nothing."""
    from repro.runtime import StreamingPipeline

    pipe = _fleet(mesh)
    pipe.ingest_many(_waves(rng, waves=3), packed=True)
    # No queries between the wave and save(): every member's state is
    # still a lazy (stacked, index) slot when state_payload reads it.
    ckdir = str(tmp_path / "ck")
    pipe.save(ckdir)
    restored = StreamingPipeline.load(ckdir, mesh)
    tail = _waves(rng, waves=1)
    pipe.ingest_many(tail, packed=True)
    restored.ingest_many(tail, packed=True)
    xs = rng.normal(size=(4, 32)).astype(np.float32)
    for name in ("m0", "m1", "m2"):
        for x in xs:
            ta, tb = pipe.submit(name, x), restored.submit(name, x)
            pipe.flush()
            restored.flush()
            np.testing.assert_allclose(
                ta.result()[0], tb.result()[0], rtol=1e-5, atol=1e-6
            )
    pipe.close()
    restored.close()


def test_ingest_packed_validates(mesh, rng):
    """Mixed pack keys and unshardable batches are rejected loudly."""
    import sys

    import repro.runtime.ingest_packed  # noqa: F401
    ipm = sys.modules["repro.runtime.ingest_packed"]

    pipe = _fleet(mesh)
    pipe.add_tenant("other", 64, protocol="P2")  # different d => different key
    pipe.ingest_many(_waves(rng, waves=1), packed=True)
    protos = {
        name: ipm.pack_target(pipe._tenant(name).adapter)
        for name in ("m0", "m1", "other")
    }
    good = rng.normal(size=(8, 32)).astype(np.float32)
    with pytest.raises(ValueError, match="share one pack_key"):
        ipm.ingest_packed(
            [(protos["m0"], good), (protos["other"], rng.normal(size=(8, 64)).astype(np.float32))]
        )
    with pytest.raises(ValueError, match="rows"):
        ipm.ingest_packed([(protos["m0"], rng.normal(size=(8, 64)).astype(np.float32))])
    assert ipm.ingest_packed([]) == {
        "tenants": 0,
        "rows": 0,
        "pad_rows": 0,
        "new_shape": False,
        "restacked": False,
    }
    pipe.close()


def test_packed_matches_serial_all_kinds_multisite():
    """All four protocol kinds, 4 paper sites: packed == serial answers.

    Matrix (P2 pack of three + a lone P1) and leverage (LP1 pair, one cold)
    tenants ride stacked launches; HH and quantile shard tenants are
    unpackable by design (weighted pairs can't be zero-padded) and take the
    serial lane of the same waves — every served answer must agree with the
    all-serial pipeline either way.
    """
    script = """
import numpy as np
import jax

from repro.runtime import StreamingPipeline, EveryKSteps
from repro.core.leverage import subspace_query

mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
rng = np.random.default_rng(0)
D = 32

def build():
    pipe = StreamingPipeline(mesh, policy=EveryKSteps(2), eps=0.2)
    for i in range(3):
        pipe.add_tenant(f"m{i}", D, protocol="P2")
    pipe.add_tenant("p1", D, protocol="P1")
    pipe.add_leverage_tenant("lev0", D, engine="shard", protocol="P1", eps=0.3)
    pipe.add_leverage_tenant("lev1", D, engine="shard", protocol="P1", eps=0.3)
    pipe.add_hh_tenant("hh", engine="shard", eps=0.1)
    pipe.add_quantile_tenant("qt", engine="shard", eps=0.1)
    return pipe

sizes = {"m0": 32, "m1": 16, "m2": 8, "p1": 32, "lev0": 16, "lev1": 16}
batches = []
for w in range(3):
    for name, n in sizes.items():
        if name == "lev1" and w == 0:
            continue
        batches.append((name, rng.normal(size=(n, D)).astype(np.float32)))
    ew = np.stack([rng.integers(0, 50, 64).astype(np.float32),
                   rng.random(64).astype(np.float32) + 0.1], axis=1)
    batches.append(("hh", ew))
    vw = np.stack([rng.normal(size=64).astype(np.float32),
                   np.ones(64, np.float32)], axis=1)
    batches.append(("qt", vw))

pa, pb = build(), build()
na = pa.ingest_many(batches, packed=True)
nb = pb.ingest_many(batches, packed=False)
assert na == nb, (na, nb)
assert pa.stats()["packed_launches"] > 0

xs = rng.normal(size=(4, D)).astype(np.float32)
for name in ["m0", "m1", "m2", "p1"]:
    for x in xs:
        ta, tb = pa.submit(name, x), pb.submit(name, x)
        pa.flush(); pb.flush()
        np.testing.assert_allclose(ta.result()[0], tb.result()[0],
                                   rtol=1e-5, atol=1e-5)
for x in xs:
    for name in ("lev0", "lev1"):
        ta, tb = pa.submit(name, subspace_query(x)), pb.submit(name, subspace_query(x))
        pa.flush(); pb.flush()
        np.testing.assert_allclose(ta.result()[0], tb.result()[0],
                                   rtol=1e-5, atol=1e-5)
np.testing.assert_allclose(pa.quantiles("qt", [0.1, 0.5, 0.9]),
                           pb.quantiles("qt", [0.1, 0.5, 0.9]), rtol=1e-5)
assert pa.heavy_hitters("hh", 0.05) == pb.heavy_hitters("hh", 0.05)
pa.close(); pb.close()
print("PACKED_EQ_OK")
"""
    out = run_multidevice(script, n_devices=4)
    assert "PACKED_EQ_OK" in out
