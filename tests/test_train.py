"""Training substrate: loss descent, FD gradient compression, fault tolerance
(checkpoint/restart determinism, elastic restore), tracker integration."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_multidevice
from repro.ckpt import latest_step, restore, save
from repro.data import TokenStream
from repro.models.config import ModelConfig
from repro.models.transformer import LM
from repro.train.step import TrainConfig, init_train_state, make_train_step

TINY = ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256, dtype="float32", remat="none",
)


def _train(n_steps, state=None, start=0, seed=0):
    lm = LM(TINY)
    tcfg = TrainConfig(peak_lr=1e-2, warmup_steps=5, total_steps=100)
    if state is None:
        state = init_train_state(lm, jax.random.key(seed), tcfg)
    step = jax.jit(make_train_step(lm, tcfg))
    ds = TokenStream(global_batch=8, seq_len=64, vocab=256, seed=0)
    losses = []
    for i in range(start, start + n_steps):
        state, m = step(state, {"tokens": jnp.asarray(ds.batch_at(i)["tokens"])})
        losses.append(float(m["loss"]))
    return state, losses


def test_loss_decreases():
    _, losses = _train(40)
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])


def test_checkpoint_restart_determinism():
    """Restarted run must produce bit-identical parameters to an
    uninterrupted run (pipeline is a pure function of (seed, step))."""
    full_state, _ = _train(20)

    state_a, _ = _train(10)
    with tempfile.TemporaryDirectory() as d:
        save(d, 10, state_a)
        assert latest_step(d) == 10
        restored, _ = restore(d, 10, state_a)
        resumed, _ = _train(10, state=restored, start=10)
    for a, b in zip(jax.tree.leaves(full_state), jax.tree.leaves(resumed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_checkpoint_corruption_detected():
    state, _ = _train(1)
    with tempfile.TemporaryDirectory() as d:
        path = save(d, 1, state)
        # corrupt one shard
        victim = next(
            f for f in sorted(os.listdir(path)) if f.endswith((".zst", ".zlib"))
        )
        with open(os.path.join(path, victim), "r+b") as f:
            f.seek(8)
            f.write(b"\x00\x00\x00\x00")
        with pytest.raises(Exception):
            restore(d, 1, state)


def test_elastic_restore_to_different_mesh():
    """A checkpoint written replicated restores onto a 2x4 mesh (and back)."""
    out = run_multidevice(
        """
import tempfile, numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.ckpt import save, restore
from repro.models.config import ModelConfig
from repro.models.transformer import LM
from repro.models.sharding import param_shardings

cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32", remat="none")
lm = LM(cfg)
params = lm.init(jax.random.key(0))
mesh = jax.make_mesh((2, 4), ("data", "model"))
sh = param_shardings(params, mesh)
with tempfile.TemporaryDirectory() as d:
    save(d, 0, params)
    resharded, _ = restore(d, 0, params, shardings=sh)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(resharded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the resharded copy really is distributed
    leaf = resharded["embed"]["table"]
    assert len(leaf.sharding.device_set) > 1
print("OK")
"""
    )
    assert "OK" in out


def test_fd_gradient_compression_trains_and_saves_comm():
    out = run_multidevice(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.data import TokenStream
from repro.models.config import ModelConfig
from repro.models.transformer import LM
from repro.train import TrainConfig, init_train_state, make_compressed_train_step
from repro.optim import FDCompressConfig

mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  d_ff=128, vocab_size=256, dtype="float32", remat="none")
lm = LM(cfg)
tc = TrainConfig(peak_lr=1e-2, warmup_steps=5, total_steps=60,
                 grad_compression=FDCompressConfig(rank=8, sketch_rows=16, min_size=2048))
state = init_train_state(lm, jax.random.key(0), tc)
step = make_compressed_train_step(lm, tc, mesh)
ds = TokenStream(global_batch=16, seq_len=64, vocab=256, seed=0)
losses = []
for i in range(35):
    state, m = step(state, {"tokens": jnp.asarray(ds.batch_at(i)["tokens"])})
    losses.append(float(m["loss"]))
assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])
ratio = float(m["comm_full_bytes"]) / float(m["comm_compressed_bytes"])
assert ratio > 2.0, ratio
print("OK ratio", ratio)
"""
    )
    assert "OK" in out


def test_tracker_rides_training_stream():
    out = run_multidevice(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.tracker import DistributedMatrixTracker

mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
rng = np.random.default_rng(0)
d = 32
tracker = DistributedMatrixTracker(mesh, d, eps=0.25, axis="data")
u = rng.normal(size=(4096, 4)) * np.array([8.0, 4.0, 2.0, 1.0])
A = (u @ rng.normal(size=(4, d))).astype(np.float32)
for i in range(0, 4096, 512):
    tracker.update(jnp.asarray(A[i:i+512]))
snap = tracker.snapshot(k=4)
# top direction of the sketch matches the true top right-singular vector
_, _, vt = np.linalg.svd(A, full_matrices=False)
cos = abs(float(np.dot(snap.basis[0], vt[0])))
assert cos > 0.95, cos
assert snap.messages["total"] < 4096
print("OK cos", cos, snap.messages)
"""
    )
    assert "OK" in out
