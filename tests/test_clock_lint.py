"""Lint-style guard: no bare wall-clock reads outside ``repro.obs``.

Every latency/lag measurement in the runtime must flow through the
injectable clock on ``Obs`` (or an explicit ``clock=`` parameter), so
tests and replays can run on virtual time and chaos runs stay
deterministic.  This test greps the source tree for direct
``time.time()`` / ``time.monotonic()`` / ``time.perf_counter()`` calls;
``obs/`` owns the real clock and is the only exemption.

Passing a clock *function* as a default (``clock: ... = time.time``) is
fine — the regex matches calls, not references.
"""
import re
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "src" / "repro"
WALL_CLOCK = re.compile(r"\btime\.(?:time|monotonic|perf_counter)\(\)")


def test_no_bare_wall_clock_outside_obs():
    offenders = []
    for path in sorted(SRC.rglob("*.py")):
        if "obs" in path.relative_to(SRC).parts[:1]:
            continue
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            if WALL_CLOCK.search(line):
                offenders.append(f"{path.relative_to(SRC)}:{lineno}: {line.strip()}")
    assert not offenders, (
        "bare wall-clock call(s) outside repro.obs — route through the "
        "injectable clock:\n" + "\n".join(offenders)
    )


def test_lint_scope_is_nonempty():
    """The glob actually covers the tree (guards against a silent rename)."""
    files = list(SRC.rglob("*.py"))
    assert len(files) > 20
    assert any("pipeline" in f.name for f in files)
