"""Leverage-score row sampling: registry harness for every spec (subspace
envelope cross-checked against the matrix tenants' exact envelope, comm vs
naive forwarding, bit-identical checkpoint round-trip), jit reservoir merge
identity, the levscore kernel vs its reference, packed serving (incl. the
empty-snapshot edge case for all four kinds), and the four-kind mixed
pipeline fresh-process restart contract.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.comm import CommReport
from repro.core.leverage import (
    decode_leverage_snapshot,
    encode_leverage_snapshot,
    lev_init,
    lev_merge,
    lev_merge_spill,
    ridge_factor,
    ridge_scores,
    run_leverage_protocol,
    score_query,
    subspace_query,
    table_scores,
    table_subspace,
)
from repro.core.quantiles import quantile_query, rank_query
from repro.data.synthetic import lowrank_stream, zipfian_stream
from repro.query import PackedRequest, QueryEngine, SketchStore
from repro.runtime import (
    EveryKSteps,
    StreamingPipeline,
    TenantQuota,
    create_protocol,
    specs,
)

L_N, L_D, L_M, L_EPS = 24_000, 16, 4, 0.2


@pytest.fixture(scope="module")
def mesh():
    return jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))


@pytest.fixture(scope="module")
def lev_stream():
    a = lowrank_stream(L_N, L_D, rank=3, seed=11)
    rng = np.random.default_rng(12)
    sites = rng.integers(0, L_M, L_N)
    xs = rng.normal(size=(24, L_D)).astype(np.float32)
    xs /= np.linalg.norm(xs, axis=1, keepdims=True)
    return a, sites, xs


# ---------------------------------------------------------------------------
# the math: oracle scoring + codec + jit reservoir laws
# ---------------------------------------------------------------------------


def test_ridge_scores_of_true_matrix_sum_to_effective_dimension():
    """sum_i tau_i = sum_j sigma_j^2 / (sigma_j^2 + lambda) when scoring A's
    own rows against A's Gram — the textbook ridge-leverage identity."""
    rng = np.random.default_rng(0)
    a = rng.normal(size=(200, 12))
    lam = 3.0
    factor = ridge_factor(a, 1.0, lam)
    scores = ridge_scores(factor, a)
    sig_sq = np.linalg.svd(a, compute_uv=False) ** 2
    d_eff = float(np.sum(sig_sq / (sig_sq + lam)))
    assert float(scores.sum()) == pytest.approx(d_eff, rel=1e-8)
    assert scores.min() >= 0.0


def test_ridge_factor_validation():
    with pytest.raises(ValueError, match="lambda"):
        ridge_factor(np.zeros((3, 2)), 1.0, 0.0)
    with pytest.raises(ValueError, match="\\(k, d\\)"):
        ridge_factor(np.zeros(3), 1.0, 1.0)
    # empty rows: the factor is I / lambda
    f = ridge_factor(np.zeros((0, 4)), 1.0, 2.0)
    np.testing.assert_allclose(f, np.eye(4) / 2.0, atol=1e-12)


def test_leverage_snapshot_codec_round_trip_and_validation():
    rng = np.random.default_rng(1)
    rows = rng.normal(size=(5, 3)).astype(np.float32)
    tab = np.concatenate(
        [rows, np.abs(rng.normal(size=(5, 1))).astype(np.float32),
         np.ones((5, 1), np.float32)], axis=1)
    enc = encode_leverage_snapshot(tab)
    r, s, w = decode_leverage_snapshot(enc)
    np.testing.assert_array_equal(r, tab[:, :3])
    np.testing.assert_array_equal(s, tab[:, 3])
    np.testing.assert_array_equal(w, tab[:, 4])
    assert encode_leverage_snapshot(np.zeros((0, 5), np.float32)).shape == (0, 5)
    with pytest.raises(ValueError, match="d\\+2"):
        encode_leverage_snapshot(np.zeros((3, 2), np.float32))
    bad = tab.copy()
    bad[0, -1] = -1.0
    with pytest.raises(ValueError, match=">= 0"):
        encode_leverage_snapshot(bad)
    bad = tab.copy()
    bad[0, -2] = np.inf
    with pytest.raises(ValueError, match="finite"):
        encode_leverage_snapshot(bad)
    with pytest.raises(ValueError, match="d\\+2"):
        decode_leverage_snapshot(np.zeros((2, 1), np.float32))


def test_lev_merge_all_pad_is_identity():
    """The all-pad reservoir is the merge identity — the property the shard
    engine's masked-collective shipping relies on (acceptance criterion)."""
    rng = np.random.default_rng(2)
    st = lev_init(8, 4)
    # build a half-full sorted state through the real merge path
    st, _ = lev_merge_spill(
        st, rng.normal(size=(5, 4)).astype(np.float32),
        np.array([5.0, 3.0, 9.0, 1.0, 7.0], np.float32),
        np.ones(5, np.float32))
    before = jax.tree.map(np.asarray, st)
    after = jax.tree.map(np.asarray, lev_merge(st, lev_init(8, 4)))
    for a, b in zip(before, after):
        np.testing.assert_array_equal(a, b)
    # and merging INTO the identity keeps every live triple
    merged = lev_merge(lev_init(8, 4), st)
    assert float(np.sum(np.asarray(merged.scores) > 0)) == 5


def test_lev_merge_spill_conserves_rows():
    """Overflow spills the dropped rows (for the residual FD) — top-cap kept
    by score, everything live accounted exactly once."""
    rng = np.random.default_rng(3)
    st = lev_init(4, 3)
    rows = rng.normal(size=(10, 3)).astype(np.float32)
    scores = np.arange(1.0, 11.0, dtype=np.float32)
    st2, spilled = lev_merge_spill(st, rows, scores, np.ones(10, np.float32))
    np.testing.assert_array_equal(np.asarray(st2.scores), [10.0, 9.0, 8.0, 7.0])
    spilled = np.asarray(spilled)
    live_spill = spilled[np.einsum("nd,nd->n", spilled, spilled) > 0]
    np.testing.assert_allclose(
        np.sort(live_spill.sum(axis=1)), np.sort(rows[:6].sum(axis=1)), rtol=1e-6)


# ---------------------------------------------------------------------------
# levscore kernel vs reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d,n", [(8, 3), (16, 64), (64, 200), (130, 257), (512, 600)])
def test_levscore_kernel_matches_reference(d, n):
    from repro.kernels.ops import levscore
    from repro.kernels.ref import ref_levscore

    rng = np.random.default_rng(d + n)
    m = rng.normal(size=(d, d)).astype(np.float32)
    m = m @ m.T / d + np.eye(d, dtype=np.float32)
    x = rng.normal(size=(n, d)).astype(np.float32)
    got = np.asarray(levscore(jnp.asarray(m), jnp.asarray(x), path="pallas"))
    want = np.asarray(ref_levscore(jnp.asarray(m), jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    # and the reference agrees with the numpy oracle the protocols use
    np.testing.assert_allclose(want, ridge_scores(m, x), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("d,n", [(16, 64), (130, 257)])
def test_levscore_backend_dispatch_paths_agree(d, n):
    """The backend-aware dispatch: forced pallas and forced xla agree to
    1e-5, and auto on CPU serves the XLA path bit-identically (the fused
    kernel is kept for real accelerators, where interpret=False)."""
    from repro.kernels.ops import levscore

    rng = np.random.default_rng(7 * d + n)
    m = rng.normal(size=(d, d)).astype(np.float32)
    m = m @ m.T / d + np.eye(d, dtype=np.float32)
    x = rng.normal(size=(n, d)).astype(np.float32)
    via_pallas = np.asarray(levscore(jnp.asarray(m), jnp.asarray(x), path="pallas"))
    via_xla = np.asarray(levscore(jnp.asarray(m), jnp.asarray(x), path="xla"))
    np.testing.assert_allclose(via_pallas, via_xla, rtol=1e-5, atol=1e-6)
    # auto == xla on CPU (interpret mode): exact, not just close
    auto = np.asarray(levscore(jnp.asarray(m), jnp.asarray(x)))
    np.testing.assert_array_equal(auto, via_xla)
    with pytest.raises(ValueError, match="levscore path"):
        levscore(jnp.asarray(m), jnp.asarray(x), path="fused")


def test_levscore_kernel_shape_validation():
    from repro.kernels.levscore import levscore_pallas

    with pytest.raises(ValueError, match="square"):
        levscore_pallas(jnp.zeros((4, 8)), jnp.zeros((8, 4)), interpret=True)
    with pytest.raises(ValueError, match="row dim"):
        levscore_pallas(jnp.zeros((8, 8)), jnp.zeros((8, 4)), interpret=True)


# ---------------------------------------------------------------------------
# registry: one harness for every registered leverage spec
# ---------------------------------------------------------------------------


def _make_leverage(spec, mesh):
    if spec.engine == "event":
        return create_protocol(
            spec.name, engine="event", kind="leverage", m=L_M, eps=L_EPS,
            d=L_D, seed=5,
        )
    return create_protocol(
        spec.name, engine="shard", kind="leverage", mesh=mesh, d=L_D, eps=L_EPS
    )


@pytest.mark.parametrize("spec", specs(kind="leverage"), ids=lambda s: f"{s.engine}-{s.name}")
def test_registry_leverage_harness(spec, lev_stream, mesh):
    """Every (engine, protocol) leverage pair: stream batches through the
    uniform interface, then check the subspace-query envelope, message
    accounting vs naive forwarding, the mass estimate, the shared table
    query path, and the checkpoint payload round-trip."""
    a, sites, xs = lev_stream
    frob = float(np.sum(a * a))
    proto = _make_leverage(spec, mesh)
    for i in range(0, L_N, 6_000):
        if spec.engine == "event":
            proto.step(a[i : i + 6_000], sites[i : i + 6_000])
        else:
            proto.step(a[i : i + 6_000])
    assert proto.rows_seen == L_N

    # eps envelope on ||A x||^2 (err_factor slack for the sampling variant)
    true = np.sum((a @ xs.T) ** 2, axis=0)
    est = proto.subspace_query_batch(xs)
    assert np.max(np.abs(est - true)) <= spec.err_factor * L_EPS * frob * (1 + 1e-5)
    # the kernel-served batch path and the single-query path agree
    assert proto.subspace_query(xs[0]) == pytest.approx(float(est[0]), rel=1e-6)

    # mass estimate tracks the true stream mass
    assert 0.5 * frob <= proto.total_weight() <= 2.0 * frob

    # comm-bound sanity: beats naive forwarding (one message per row)
    rep = proto.comm_report()
    assert isinstance(rep, CommReport)
    assert 0 < rep.total < L_N

    # the batch query surface rides the same published-table code path
    np.testing.assert_allclose(
        est, table_subspace(proto.sampled_rows(), xs), rtol=1e-4, atol=1e-2)

    # score queries are finite, non-negative, and match the numpy oracle
    sc = proto.score_batch(xs)
    np.testing.assert_allclose(
        sc, table_scores(proto.sampled_rows(), xs, proto.lam()),
        rtol=1e-3, atol=1e-5)
    assert np.all(sc >= -1e-6) and np.all(np.isfinite(sc))

    # snapshot encoding is valid store input
    enc = proto.snapshot_matrix()
    assert enc.dtype == np.float32 and enc.shape[1] == L_D + 2

    # checkpoint round-trip: a fresh protocol restored from the payload
    # continues the stream identically (the pipeline-restart contract)
    arrays, meta = proto.state_payload()
    clone = _make_leverage(spec, mesh)
    clone.restore_payload({k: np.asarray(v) for k, v in arrays.items()}, meta)
    tail = a[:5_000]
    if spec.engine == "event":
        proto.step(tail, sites[:5_000])
        clone.step(tail, sites[:5_000])
    else:
        proto.step(tail)
        clone.step(tail)
    np.testing.assert_array_equal(proto.sampled_rows(), clone.sampled_rows())
    assert proto.total_weight() == clone.total_weight()
    assert proto.comm_report() == clone.comm_report()


def test_leverage_scores_prefer_novel_directions_over_norm():
    """The motivation: squared-norm scoring (matrix P3's sampling key)
    cannot distinguish a row inside the already-covered subspace from an
    equal-norm row in a fresh direction; ridge leverage scoring ranks the
    novel one far higher — score ~ ||a||^2 / (sigma^2 + lambda) per
    direction, so a well-covered direction is discounted by its own
    energy.  This is the structural signal the fourth kind adds, and it
    is deterministic."""
    rng = np.random.default_rng(6)
    q = np.linalg.qr(rng.normal(size=(6, 6)))[0]
    # a sketch whose rows concentrate 1e6 of energy in q[0]; q[5] unseen
    b = np.sqrt(np.array([1e6, 3e5, 1e5]))[:, None] * q[:3]
    lam = 1e4
    factor = ridge_factor(b, 1.0, lam)
    scale = 100.0  # equal norms: the norm key sees no difference at all
    covered, novel = q[0] * scale, q[5] * scale
    scores = ridge_scores(factor, np.stack([covered, novel]))
    assert scores[1] > 50.0 * scores[0]
    # and the exact per-direction identity: tau = ||a||^2 / (sigma^2 + lam)
    assert scores[0] == pytest.approx(scale**2 / (1e6 + lam), rel=1e-6)
    assert scores[1] == pytest.approx(scale**2 / lam, rel=1e-6)


def test_leverage_empty_batch_is_identity(mesh):
    """An empty (0, d) ingest batch is a no-op for every leverage engine
    (matrix/hh/quantile shard tenants already accept them — a producer
    emitting an occasional empty batch must not kill leverage tenants)."""
    for engine in ("event", "shard"):
        kw = {"m": 2, "d": 4} if engine == "event" else {"mesh": mesh, "d": 4}
        proto = create_protocol("P1", engine=engine, kind="leverage", eps=0.5, **kw)
        proto.step(np.zeros((0, 4), np.float32))
        proto.step(np.full((2, 4), 2.0, np.float32))
        before = proto.sampled_rows().copy()
        proto.step(np.zeros((0, 4), np.float32))
        np.testing.assert_array_equal(proto.sampled_rows(), before)
        assert proto.rows_seen == 2


def test_leverage_rejects_malformed_ingest(mesh):
    """Wrong-width and non-finite row batches are rejected at the ingest
    seam, for both engines."""
    for engine in ("event", "shard"):
        kw = {"m": 2, "d": 4} if engine == "event" else {"mesh": mesh, "d": 4}
        proto = create_protocol("P1", engine=engine, kind="leverage", eps=0.5, **kw)
        with pytest.raises(ValueError, match="\\(n, 4\\)"):
            proto.step(np.zeros((3, 5), np.float32))
        with pytest.raises(ValueError, match="finite"):
            proto.step(np.array([[1.0, np.inf, 0.0, 0.0]]))
    with pytest.raises(KeyError, match="unknown leverage protocol"):
        run_leverage_protocol("P9", np.zeros((1, 4)), np.zeros(1, np.int64), 1, 0.5)


def test_lev_p1_shard_multidevice():
    """LP1 on a real 8-shard mesh: every shard is a paper site, the masked
    all_gather ships high-score candidates + residual sketches, and the
    folded coordinator meets the subspace envelope at sub-stream
    communication."""
    from conftest import run_multidevice

    out = run_multidevice(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.distributed import (
    ProtocolConfig, make_protocol_runner, lev_p1_table, lev_p1_mass)
from repro.core.leverage import table_subspace
from repro.data.synthetic import lowrank_stream

m, eps, n, d = 8, 0.2, 16384, 16
mesh = Mesh(np.array(jax.devices()).reshape(m), ("sites",))
a = lowrank_stream(n, d, rank=3, seed=5)
frob = float(np.sum(a * a))
cfg = ProtocolConfig(eps=eps, m=m, d=d, axis="sites").resolved()
state, step = make_protocol_runner("LP1", cfg, mesh)
batch = 512
for t in range(n // (m * batch)):
    lo, hi = t * m * batch, (t + 1) * m * batch
    state = step(state, jnp.asarray(a[lo:hi]))
tab = lev_p1_table(cfg, state)
mass = lev_p1_mass(state)
assert 0.6 * frob <= mass <= 1.4 * frob, (mass, frob)
rng = np.random.default_rng(7)
xs = rng.normal(size=(16, d)).astype(np.float32)
xs /= np.linalg.norm(xs, axis=1, keepdims=True)
true = np.sum((a @ xs.T) ** 2, axis=0)
worst = float(np.max(np.abs(table_subspace(tab, xs) - true))) / frob
assert worst <= 1.5 * eps, worst
c = state.comm
total = int(c.scalar_msgs) + int(c.row_msgs) + int(c.broadcast_events) * m
assert 0 < total < n, total
print("OK", worst, total)
"""
    )
    assert "OK" in out


# ---------------------------------------------------------------------------
# engine: packed leverage serving + cross-kind empty snapshots
# ---------------------------------------------------------------------------


@pytest.fixture()
def four_kind_store(lev_stream):
    a, sites, _ = lev_stream
    rng = np.random.default_rng(21)
    store = SketchStore()
    store.publish("mat", rng.normal(size=(12, L_D)).astype(np.float32),
                  frob=10.0, eps=0.1)
    store.publish("hh", np.array([[1.0, 5.0], [7.0, 3.0]], np.float32),
                  frob=8.0, eps=0.1, meta={"workload": "hh"})
    store.publish("q", np.array([[0.0, 2.0], [1.0, 4.0]], np.float32),
                  frob=4.0, eps=0.1, meta={"workload": "quantile"})
    res = run_leverage_protocol("P1", a[:6000], sites[:6000], L_M, L_EPS, seed=2)
    store.publish("lev", encode_leverage_snapshot(res.table), frob=res.f_hat,
                  eps=L_EPS, meta={"workload": "leverage", "lam": res.lam})
    return store


def test_engine_packed_mixed_four_kinds_equals_serial(four_kind_store, lev_stream):
    _, _, xs = lev_stream
    engine = QueryEngine(four_kind_store)
    rng = np.random.default_rng(22)
    reqs = [
        PackedRequest("mat", rng.normal(size=(5, L_D)).astype(np.float32)),
        PackedRequest("lev", np.stack([subspace_query(xs[0]), score_query(xs[1]),
                                       subspace_query(xs[2])])),
        PackedRequest("hh", np.array([[1.0], [2.0]], np.float32)),
        PackedRequest("q", np.stack([rank_query(0.5), quantile_query(0.5)])),
    ]
    results = engine.query_packed(reqs)
    assert [r.path for r in results] == ["pallas", "leverage", "hh", "quantile"]
    for req, res in zip(reqs, results):
        serial = engine.query_batch(req.x, tenant=req.tenant)
        np.testing.assert_allclose(res.estimates, serial.estimates, rtol=1e-5)
        assert res.error_bound == serial.error_bound


def test_engine_leverage_query_validation(four_kind_store):
    engine = QueryEngine(four_kind_store)
    with pytest.raises(ValueError, match="\\[mode, x\\]"):
        engine.query_batch(np.zeros((2, 3), np.float32), tenant="lev")
    bad = np.zeros((1, L_D + 1), np.float32)
    bad[0, 0] = 7.0
    with pytest.raises(ValueError, match="mode"):
        engine.query_batch(bad, tenant="lev")


def test_engine_leverage_matches_oracle_paths(four_kind_store, lev_stream):
    """The kernel-served engine answers equal the shared numpy table paths
    (subspace via quadform, score via levscore + the snapshot's pinned
    ridge)."""
    _, _, xs = lev_stream
    engine = QueryEngine(four_kind_store)
    snap = four_kind_store.get("lev")
    sub = engine.query_batch(
        np.stack([subspace_query(x) for x in xs]), tenant="lev").estimates
    np.testing.assert_allclose(
        sub, table_subspace(snap.matrix, xs), rtol=1e-4, atol=1e-2)
    sc = engine.query_batch(
        np.stack([score_query(x) for x in xs]), tenant="lev").estimates
    np.testing.assert_allclose(
        sc, table_scores(snap.matrix, xs, float(snap.meta["lam"])),
        rtol=1e-3, atol=1e-5)


def test_engine_leverage_factor_cache_hits_on_pinned_version(four_kind_store, lev_stream):
    """Repeated score sweeps against an unchanged snapshot version reuse
    the cached ridge factor instead of redoing the O(d^3) pinv — the
    leverage twin of the matrix path's spectrum cache."""
    _, _, xs = lev_stream
    engine = QueryEngine(four_kind_store)
    q = np.stack([score_query(x) for x in xs])
    first = engine.query_batch(q, tenant="lev").estimates
    misses = engine.cache_misses
    again = engine.query_batch(q, tenant="lev").estimates
    np.testing.assert_array_equal(first, again)
    assert engine.cache_misses == misses and engine.cache_hits >= 1


def test_packed_sweep_serves_empty_snapshots_for_all_four_kinds():
    """A tenant whose latest snapshot is empty (zero published rows) serves
    zeros inside a packed sweep rather than raising — a cold tenant must
    never wedge the sweep for the others (regression: satellite of PR 5)."""
    store = SketchStore()
    store.publish("mat", np.zeros((0, 8), np.float32), frob=0.0, eps=0.1)
    store.publish("hh", np.zeros((0, 2), np.float32), frob=0.0, eps=0.1,
                  meta={"workload": "hh"})
    store.publish("q", np.zeros((0, 2), np.float32), frob=0.0, eps=0.1,
                  meta={"workload": "quantile"})
    store.publish("lev", np.zeros((0, 10), np.float32), frob=0.0, eps=0.1,
                  meta={"workload": "leverage", "lam": 0.5})
    engine = QueryEngine(store)
    x = np.ones(8, np.float32)
    reqs = [
        PackedRequest("mat", np.stack([x, 2 * x])),
        PackedRequest("hh", np.array([[3.0]], np.float32)),
        PackedRequest("q", np.stack([rank_query(1.0), quantile_query(0.5)])),
        PackedRequest("lev", np.stack([subspace_query(x), score_query(x)])),
    ]
    results = engine.query_packed(reqs)
    for res in results[:-1]:
        np.testing.assert_array_equal(res.estimates, 0.0)
    # leverage: the subspace estimate is zero; the score of x against an
    # empty sample is the lambda-only prior ||x||^2 / lambda — finite, not
    # an error (an empty sample means "maximally novel").
    lev = results[-1].estimates
    assert lev[0] == 0.0
    assert lev[1] == pytest.approx(8.0 / 0.5, rel=1e-5)
    # serial path agrees with the packed sweep on every kind
    for req, res in zip(reqs, results):
        np.testing.assert_array_equal(
            engine.query_batch(req.x, tenant=req.tenant).estimates, res.estimates)


# ---------------------------------------------------------------------------
# pipeline: all four kinds, fresh-process restart
# ---------------------------------------------------------------------------


def test_pipeline_leverage_tenant_validation(mesh):
    pipe = StreamingPipeline(mesh, eps=0.2, policy=EveryKSteps(1))
    pipe.add_leverage_tenant("lev", 8, m=2)
    with pytest.raises(ValueError, match="already registered"):
        pipe.add_leverage_tenant("lev", 8)
    with pytest.raises(ValueError, match="engine"):
        pipe.add_leverage_tenant("lev2", 8, engine="bogus")
    pipe.ingest("lev", np.zeros((4, 8), np.float32) + 1.0)
    with pytest.raises(ValueError, match="\\[mode, x\\]"):
        pipe.submit("lev", np.zeros(8, np.float32))
    bad = np.zeros(9, np.float32)
    bad[0] = 5.0
    with pytest.raises(ValueError, match="mode"):
        pipe.submit("lev", bad)
    # the published-sample accessor works for leverage tenants ...
    rows, scores, weights = pipe.sampled_rows("lev")
    assert rows.shape[1] == 8 and scores.shape == weights.shape
    # ... and type-checks against a non-leverage tenant
    pipe.add_tenant("mat", 8)
    pipe.ingest("mat", jnp.ones((4, 8), jnp.float32))
    with pytest.raises(ValueError, match="not a leverage tenant"):
        pipe.sampled_rows("mat")


def _four_kind_pipeline(mesh):
    """One pipeline hosting all four registered workload kinds."""
    pipe = StreamingPipeline(mesh, eps=0.25, policy=EveryKSteps(1))
    pipe.add_tenant("mat", 16, quota=TenantQuota(max_pending=4, priority=1))
    pipe.add_hh_tenant("clicks", eps=0.05, protocol="P1", engine="event", m=4)
    pipe.add_quantile_tenant("lat", eps=0.05, protocol="P1", engine="event", m=4)
    pipe.add_leverage_tenant("lev-ev", 16, eps=0.2, protocol="P1",
                             engine="event", m=4,
                             quota=TenantQuota(max_pending=8, priority=5))
    pipe.add_leverage_tenant("lev-p2", 16, eps=0.3, protocol="P2",
                             engine="event", m=4, seed=3)
    pipe.add_leverage_tenant("lev-sh", 16, eps=0.2, protocol="P1",
                             engine="shard")
    return pipe


def _four_kind_feed():
    a = lowrank_stream(2048, 16, rank=3, seed=51)
    keys, w = zipfian_stream(8000, beta=100.0, universe=1000, seed=52)
    hh_pairs = np.stack([keys.astype(np.float32), w.astype(np.float32)], axis=1)
    rng = np.random.default_rng(53)
    q_pairs = np.stack([rng.lognormal(3.0, 1.0, 8000).astype(np.float32),
                        rng.uniform(1.0, 3.0, 8000).astype(np.float32)], axis=1)
    return a, hh_pairs, q_pairs


def _four_kind_ingest(pipe, a, hh_pairs, q_pairs, rounds):
    for i in rounds:
        pipe.ingest("mat", jnp.asarray(a[i * 512 : (i + 1) * 512]))
        pipe.ingest("clicks", hh_pairs[i * 2000 : (i + 1) * 2000])
        pipe.ingest("lat", q_pairs[i * 2000 : (i + 1) * 2000])
        for lev in ("lev-ev", "lev-p2", "lev-sh"):
            pipe.ingest(lev, a[i * 512 : (i + 1) * 512])


def _four_kind_answers(pipe, a, hh_pairs, q_pairs):
    """Resume ingest on the second half of every feed, then query all kinds."""
    _four_kind_ingest(pipe, a, hh_pairs, q_pairs, (2, 3))
    x = np.random.default_rng(54).normal(size=16).astype(np.float32)
    tickets = [
        pipe.submit("mat", x),
        pipe.submit("clicks", np.array([1.0], np.float32)),
        pipe.submit("lat", quantile_query(0.9)),
        pipe.submit("lev-ev", subspace_query(x)),
        pipe.submit("lev-ev", score_query(x)),
        pipe.submit("lev-p2", subspace_query(x)),
        pipe.submit("lev-sh", subspace_query(x)),
    ]
    pipe.flush()
    out = [v for t in tickets for v in t.result()]
    out += [float(pipe.stats(t).live_frob) for t in pipe.tenants()]
    out += [float(pipe.stats(t).comm_total) for t in pipe.tenants()]
    rows, scores, weights = pipe.sampled_rows("lev-ev")
    out += [float(rows.sum()), float(scores.sum()), float(weights.sum())]
    return np.array(out, np.float64)


def test_pipeline_four_kinds_restart_fresh_process(mesh, tmp_path):
    """The PR acceptance loop: one pipeline hosts matrix + HH + quantile +
    leverage tenants, serves subspace queries within the eps envelope
    through the packed path (cross-checked against the matrix tenant's
    exact envelope), and after save -> fresh-process load resumes ingest
    and answers bit-identically."""
    from conftest import run_multidevice

    pipe = _four_kind_pipeline(mesh)
    a, hh_pairs, q_pairs = _four_kind_feed()
    _four_kind_ingest(pipe, a, hh_pairs, q_pairs, (0, 1))
    assert {pipe.workload(t) for t in pipe.tenants()} == {
        "matrix", "hh", "quantile", "leverage"}

    # leverage subspace answers agree with the exact ||A x||^2 within the
    # combined envelopes, and with the matrix tenant's answer within the
    # sum of the two certificates (the cross-check acceptance criterion)
    half = a[:1024]
    frob_half = float(np.sum(half * half))
    rng = np.random.default_rng(55)
    for x in rng.normal(size=(4, 16)).astype(np.float32):
        x /= np.linalg.norm(x)
        true = float(np.sum((half @ x) ** 2))
        t_lev = pipe.submit("lev-ev", subspace_query(x))
        t_mat = pipe.submit("mat", x)
        pipe.flush()
        lev_est, lev_bound, _ = t_lev.result()
        mat_est, mat_bound, _ = t_mat.result()
        assert abs(lev_est - true) <= lev_bound * (1 + 1e-5)
        assert abs(lev_est - mat_est) <= (lev_bound + mat_bound) * (1 + 1e-5)

    # -- checkpoint, then resume in THIS process --
    ckdir = str(tmp_path / "four_kinds_ck")
    pipe.save(ckdir)
    want = _four_kind_answers(pipe, a, hh_pairs, q_pairs)

    # -- fresh-process restart: load must answer bit-identically --
    tests_dir = os.path.dirname(os.path.abspath(__file__))
    script = f"""
import sys
sys.path.insert(0, {tests_dir!r})
import jax, numpy as np
from repro.runtime import StreamingPipeline
from test_leverage import _four_kind_answers, _four_kind_feed

mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
pipe = StreamingPipeline.load({ckdir!r}, mesh)
a, hh_pairs, q_pairs = _four_kind_feed()
print("ANSWERS=" + _four_kind_answers(pipe, a, hh_pairs, q_pairs).tobytes().hex())
"""
    out = run_multidevice(script, n_devices=1)
    got_hex = [ln for ln in out.splitlines() if ln.startswith("ANSWERS=")][0]
    got = np.frombuffer(bytes.fromhex(got_hex.removeprefix("ANSWERS=")), np.float64)
    np.testing.assert_array_equal(got, want)
