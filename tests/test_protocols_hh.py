"""Paper Section 4: weighted heavy-hitter protocols — error + communication."""
import math

import numpy as np
import pytest

from repro.core.hh import exact_heavy_hitters
from repro.core.protocols import HH_STREAMS, run_hh_protocol
from repro.data.synthetic import site_assignment, zipfian_stream

N, M, EPS, PHI, BETA = 60_000, 10, 0.02, 0.05, 100.0


@pytest.fixture(scope="module")
def stream():
    keys, w = zipfian_stream(N, beta=BETA, universe=5000, seed=3)
    sites = site_assignment(N, M, seed=3)
    truth = exact_heavy_hitters(keys, w, PHI)
    return keys, w, sites, truth


@pytest.mark.parametrize("proto", ["P1", "P2", "P3", "P3wr", "P4"])
def test_hh_error_bound(stream, proto):
    keys, w, sites, (hh, totals, W) = stream
    res = run_hh_protocol(proto, keys, w, sites, M, EPS, seed=1)
    worst = max(abs(totals[e] - res.estimates.get(e, 0.0)) / W for e in totals)
    # deterministic protocols must meet eps exactly; randomized get slack
    limit = EPS + 1e-6 if proto in ("P1", "P2") else 2 * EPS
    assert worst <= limit, (proto, worst)


@pytest.mark.parametrize("proto", ["P1", "P2", "P3", "P4"])
def test_hh_recall(stream, proto):
    keys, w, sites, (hh, totals, W) = stream
    res = run_hh_protocol(proto, keys, w, sites, M, EPS, seed=2)
    returned = set(res.heavy_hitters(PHI))
    assert set(hh).issubset(returned), (proto, hh, returned)


def test_hh_p2_beats_p1_messages(stream):
    keys, w, sites, _ = stream
    m1 = run_hh_protocol("P1", keys, w, sites, M, EPS).comm.total(M)
    m2 = run_hh_protocol("P2", keys, w, sites, M, EPS).comm.total(M)
    assert m2 < m1, "P2 (m/eps) must beat P1 (m/eps^2) on messages"


def test_hh_p2_message_bound(stream):
    """O((m/eps) log(beta N)) with a generous constant."""
    keys, w, sites, _ = stream
    res = run_hh_protocol("P2", keys, w, sites, M, EPS)
    bound = 40 * (M / EPS) * math.log2(BETA * N)
    assert res.comm.total(M) <= bound


def test_hh_all_protocols_beat_naive(stream):
    keys, w, sites, _ = stream
    for proto in ["P1", "P2", "P3", "P4"]:
        msgs = run_hh_protocol(proto, keys, w, sites, M, EPS).comm.total(M)
        assert msgs < N, (proto, msgs)


@pytest.mark.parametrize("proto", sorted(HH_STREAMS))
def test_hh_stream_batches_match_one_shot(stream, proto):
    """The resumable stream classes continue event-at-a-time semantics
    exactly: feeding the stream in batches reproduces the historical
    one-shot run bit-for-bit (estimates, w_hat, and message log), RNG
    draws included.  P3wr is the documented exception — its uniform draws
    are blocked per step, so only a single whole-stream step reproduces
    the historical message count (estimates still agree)."""
    keys, w, sites, _ = stream
    eng = HH_STREAMS[proto](M, EPS, np.random.default_rng(9))
    splits = 1 if proto == "P3wr" else 4
    nb = N // splits
    for i in range(splits):
        lo, hi = i * nb, (i + 1) * nb
        eng.step(keys[lo:hi], w[lo:hi], sites[lo:hi])
    got = eng.result()
    want = run_hh_protocol(proto, keys, w, sites, M, EPS, seed=9)
    assert got.estimates == want.estimates
    assert got.w_hat == want.w_hat
    assert got.comm == want.comm


@pytest.mark.parametrize("proto", sorted(HH_STREAMS))
def test_hh_stream_state_round_trip_mid_stream(stream, proto):
    """state_dict/load_state mid-stream: a fresh stream restored from the
    snapshot finishes the stream identically to the uninterrupted one."""
    keys, w, sites, _ = stream
    half = N // 2
    eng = HH_STREAMS[proto](M, EPS, np.random.default_rng(11))
    eng.step(keys[:half], w[:half], sites[:half])
    clone = HH_STREAMS[proto](M, EPS, np.random.default_rng(0))
    clone.load_state(eng.state_dict())
    for e in (eng, clone):
        e.step(keys[half:], w[half:], sites[half:])
    got, want = clone.result(), eng.result()
    assert got.estimates == want.estimates
    assert got.w_hat == want.w_hat
    assert got.comm == want.comm


def test_hh_message_scaling_with_eps(stream):
    """Communication grows as eps shrinks (sanity on the threshold logic)."""
    keys, w, sites, _ = stream
    loose = run_hh_protocol("P2", keys, w, sites, M, 0.05).comm.total(M)
    tight = run_hh_protocol("P2", keys, w, sites, M, 0.005).comm.total(M)
    assert tight > loose
