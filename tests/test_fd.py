"""Frequent Directions: paper guarantees, mergeability, JAX-vs-numpy parity."""
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property-based tests skip gracefully on minimal installs
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
except ModuleNotFoundError:
    hypothesis = None

from repro.core.fd import (
    FDSketch,
    fd_init,
    fd_matrix,
    fd_merge,
    fd_query,
    fd_update_stream,
)

SLACK = 1e-4  # fp slack on the exact-arithmetic bounds


def _lowrank(rng, n, d, rank=5, noise=0.05):
    u = rng.normal(size=(n, rank)) * (np.arange(rank, 0, -1) ** 2)
    return u @ rng.normal(size=(rank, d)) + noise * rng.normal(size=(n, d))


def test_fd_covariance_bound(rng):
    n, d, l = 3000, 32, 16
    a = _lowrank(rng, n, d)
    sk = FDSketch(l, d)
    sk.extend(a)
    err = sk.covariance_error(a)
    assert err <= 2.0 / l + SLACK
    # the instance-specific bound is tighter and must also hold
    assert err * np.sum(a * a) <= sk.delta_sum + SLACK * np.sum(a * a)


def test_fd_directional_invariant(rng):
    n, d, l = 2000, 24, 12
    a = _lowrank(rng, n, d)
    st_ = fd_update_stream(fd_init(l, d), jnp.asarray(a, jnp.float32))
    frob = float(np.sum(a * a))
    for _ in range(25):
        x = rng.normal(size=d)
        x /= np.linalg.norm(x)
        ax = float(np.sum((a @ x) ** 2))
        bx = float(fd_query(st_, jnp.asarray(x, jnp.float32)))
        # 0 <= ||Ax||^2 - ||Bx||^2 <= delta_sum   (paper Section 3)
        assert ax - bx >= -SLACK * frob
        assert ax - bx <= float(st_.delta_sum) + SLACK * frob


def test_fd_jax_matches_numpy(rng):
    n, d, l = 512, 16, 8
    a = _lowrank(rng, n, d).astype(np.float32)
    sk = FDSketch(l, d)
    # numpy oracle consumes in l-row chunks to match the JAX batched variant
    for i in range(0, n, l):
        sk.extend(a[i : i + l])
        if sk.fill > l:
            sk._shrink()
    st_ = fd_update_stream(fd_init(l, d), jnp.asarray(a))
    ga = sk.matrix()[:l]
    gb = np.asarray(fd_matrix(st_))
    # sketches are equal up to sign/rotation: compare Gram matrices
    np.testing.assert_allclose(ga.T @ ga, gb.T @ gb, rtol=2e-3, atol=2e-2)


def test_fd_merge_error_adds(rng):
    n, d, l = 2000, 24, 16
    a = _lowrank(rng, n, d)
    st1 = fd_update_stream(fd_init(l, d), jnp.asarray(a[: n // 2], jnp.float32))
    st2 = fd_update_stream(fd_init(l, d), jnp.asarray(a[n // 2 :], jnp.float32))
    merged = fd_merge(st1, st2)
    b = np.asarray(fd_matrix(merged))
    err = np.linalg.norm(a.T @ a - b.T @ b, 2)
    assert err <= float(merged.delta_sum) + SLACK * np.sum(a * a)
    assert float(merged.frob) == pytest.approx(np.sum(a * a), rel=1e-3)
    assert int(merged.n_seen) == n


def test_fd_zero_rows_are_free(rng):
    d, l = 16, 8
    a = rng.normal(size=(64, d)).astype(np.float32)
    st1 = fd_update_stream(fd_init(l, d), jnp.asarray(a))
    padded = np.concatenate([a, np.zeros((40, d), np.float32)])
    st2 = fd_update_stream(fd_init(l, d), jnp.asarray(padded))
    assert int(st2.n_seen) == int(st1.n_seen)
    assert float(st2.frob) == pytest.approx(float(st1.frob), rel=1e-5)


def test_fd_property_invariant():
    """For arbitrary matrices: 0 <= ||Ax||^2 - ||Bx||^2 <= 2||A||_F^2 / l.

    Hypothesis when installed, else a seeded sweep over the same check.
    """
    from conftest import run_property

    def check(a, l):
        d = a.shape[1]
        st_ = fd_update_stream(fd_init(l, d), jnp.asarray(a))
        frob = float(np.sum(a.astype(np.float64) ** 2))
        x = np.ones(d) / np.sqrt(d)
        ax = float(np.sum((a @ x) ** 2))
        bx = float(fd_query(st_, jnp.asarray(x, jnp.float32)))
        slack = 1e-3 * frob + 1e-4
        assert ax - bx >= -slack
        assert ax - bx <= 2.0 * frob / l + slack

    rng = np.random.default_rng(0)

    def seeded():
        for _ in range(25):
            n, d = int(rng.integers(20, 61)), int(rng.integers(4, 11))
            a = rng.uniform(-5, 5, size=(n, d)).astype(np.float32)
            yield {"a": a, "l": int(rng.integers(3, 9))}

    run_property(
        check,
        given=lambda: {
            "a": hnp.arrays(
                np.float32,
                st.tuples(st.integers(20, 60), st.integers(4, 10)),
                elements=st.floats(-5, 5, width=32),
            ),
            "l": st.integers(3, 8),
        },
        cases=seeded(),
        max_examples=25,
    )
