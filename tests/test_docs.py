"""The mkdocs site stays truthful.

Two contracts:

* ``docs/protocols.md``'s paper-to-code tables and the protocol registry
  must agree in BOTH directions — every ``kind:engine:name`` coordinate in
  the page resolves, every registered spec appears in the page, and all
  four workload kinds are covered.
* every internal link on every site page resolves — relative paths point
  at real files and ``#anchors`` match a real heading slug (what
  ``mkdocs build --strict`` enforces in CI, checked here without needing
  mkdocs installed).
"""
import os
import re

from repro.runtime import get_spec, specs

DOCS_DIR = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                        "docs")
PROTOCOLS = os.path.join(DOCS_DIR, "protocols.md")
KINDS = ("matrix", "hh", "quantile", "leverage")
COORD = re.compile(r"`(matrix|hh|quantile|leverage):(event|shard):([A-Za-z0-9]+)`")
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def _doc_coords() -> set[tuple[str, str, str]]:
    with open(PROTOCOLS) as f:
        return {m.groups() for m in COORD.finditer(f.read())}


def _pages() -> list[str]:
    return sorted(
        os.path.join(DOCS_DIR, name)
        for name in os.listdir(DOCS_DIR)
        if name.endswith(".md")
    )


# ---------------------------------------------------------------------------
# table <-> registry, both directions, all four kinds
# ---------------------------------------------------------------------------


def test_protocols_page_exists_and_covers_all_kinds():
    assert os.path.exists(PROTOCOLS), "docs/protocols.md is part of the repo contract"
    coords = _doc_coords()
    assert len(coords) >= 13  # the full four-kind protocol family is mapped
    assert {k for (k, _, _) in coords} == set(KINDS)


def test_every_doc_coordinate_resolves_in_registry():
    for kind, engine, name in sorted(_doc_coords()):
        spec = get_spec(name, engine, kind)  # raises KeyError if stale
        assert (spec.kind, spec.engine, spec.name) == (kind, engine, name)


def test_every_registered_spec_is_documented():
    coords = _doc_coords()
    assert {s.kind for s in specs()} == set(KINDS)
    missing = [
        f"{s.kind}:{s.engine}:{s.name}"
        for s in specs()
        if (s.kind, s.engine, s.name) not in coords
    ]
    assert not missing, f"add to docs/protocols.md paper-to-code tables: {missing}"


# ---------------------------------------------------------------------------
# link checker: internal anchors + relative paths resolve on every page
# ---------------------------------------------------------------------------


def _slugify(heading: str) -> str:
    """Python-Markdown toc slug (what mkdocs anchors headings with)."""
    text = re.sub(r"`([^`]*)`", r"\1", heading)  # code spans keep their text
    text = re.sub(r"[^\w\s-]", "", text).strip().lower()
    return re.sub(r"[\s]+", "-", text)


def _heading_slugs(path: str) -> set[str]:
    with open(path) as f:
        text = f.read()
    # Strip fenced code blocks: '# comment' lines inside them aren't headings.
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    return {_slugify(h) for h in HEADING.findall(text)}


def _links(path: str) -> list[str]:
    with open(path) as f:
        text = f.read()
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    return LINK.findall(text)


def test_site_pages_internal_links_resolve():
    pages = _pages()
    assert len(pages) >= 4  # index, protocols, serving, extending
    problems = []
    for page in pages:
        for link in _links(page):
            if link.startswith(("http://", "https://", "mailto:")):
                continue
            target, _, anchor = link.partition("#")
            target_path = (
                os.path.normpath(os.path.join(os.path.dirname(page), target))
                if target
                else page
            )
            if not os.path.exists(target_path):
                problems.append(f"{os.path.basename(page)}: missing file {link!r}")
                continue
            if anchor and target_path.endswith(".md"):
                if anchor not in _heading_slugs(target_path):
                    problems.append(
                        f"{os.path.basename(page)}: dead anchor {link!r}"
                    )
    assert not problems, "\n".join(problems)


def test_site_pages_do_not_link_outside_docs():
    """mkdocs --strict warns (-> fails) on links escaping the docs dir."""
    for page in _pages():
        for link in _links(page):
            if link.startswith(("http://", "https://", "mailto:", "#")):
                continue
            target = link.partition("#")[0]
            resolved = os.path.normpath(os.path.join(os.path.dirname(page), target))
            assert resolved.startswith(DOCS_DIR + os.sep), (
                f"{os.path.basename(page)} links outside docs/: {link!r}"
            )


def test_mkdocs_config_lists_every_page():
    """mkdocs.yml nav and the docs dir agree (strict mode flags orphans)."""
    cfg = os.path.join(os.path.dirname(DOCS_DIR), "mkdocs.yml")
    assert os.path.exists(cfg)
    with open(cfg) as f:
        text = f.read()
    for page in _pages():
        assert os.path.basename(page) in text, (
            f"{os.path.basename(page)} missing from mkdocs.yml nav"
        )
