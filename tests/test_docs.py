"""docs/ARCHITECTURE.md stays truthful: its paper-to-code table and the
protocol registry must agree in BOTH directions — every coordinate in the
table resolves, and every registered spec appears in the table."""
import os
import re

from repro.runtime import get_spec, specs

DOC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "docs", "ARCHITECTURE.md")
COORD = re.compile(r"`(matrix|hh|quantile):(event|shard):([A-Za-z0-9]+)`")


def _doc_coords() -> set[tuple[str, str, str]]:
    with open(DOC) as f:
        return {m.groups() for m in COORD.finditer(f.read())}


def test_architecture_doc_exists_and_has_coords():
    assert os.path.exists(DOC), "docs/ARCHITECTURE.md is part of the repo contract"
    assert len(_doc_coords()) >= 10  # the full protocol family is mapped


def test_every_doc_coordinate_resolves_in_registry():
    for kind, engine, name in sorted(_doc_coords()):
        spec = get_spec(name, engine, kind)  # raises KeyError if stale
        assert (spec.kind, spec.engine, spec.name) == (kind, engine, name)


def test_every_registered_spec_is_documented():
    coords = _doc_coords()
    missing = [
        f"{s.kind}:{s.engine}:{s.name}"
        for s in specs()
        if (s.kind, s.engine, s.name) not in coords
    ]
    assert not missing, f"add to docs/ARCHITECTURE.md paper-to-code table: {missing}"
