"""Per-architecture smoke tests (deliverable f): REDUCED same-family configs,
one forward + one train step on CPU, asserting shapes and no NaNs; plus
decode-vs-forward consistency for every layer-kind family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_NAMES, get_config, reduced_config
from repro.models.transformer import LM
from repro.train.step import TrainConfig, init_train_state, make_train_step

BATCH, SEQ = 2, 32


def _batch_for(cfg, key=0):
    rng = np.random.default_rng(key)
    n_front = cfg.n_frontend_tokens if cfg.frontend == "patch" else 0
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(BATCH, SEQ - n_front)), jnp.int32
        )
    }
    if n_front:
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(BATCH, n_front, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced_config(get_config(arch))
    lm = LM(cfg)
    batch = _batch_for(cfg)
    tcfg = TrainConfig(peak_lr=1e-3, warmup_steps=2, total_steps=10)
    state = init_train_state(lm, jax.random.key(0), tcfg)

    logits, _ = lm.forward(
        state.params, batch["tokens"], vision_embeds=batch.get("vision_embeds")
    )
    assert logits.shape == (BATCH, batch["tokens"].shape[1], cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: NaN logits"

    step = jax.jit(make_train_step(lm, tcfg))
    new_state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: non-finite loss"
    assert loss == pytest.approx(np.log(cfg.vocab_size), rel=0.5), f"{arch}: loss {loss}"
    # params actually changed
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(new_state.params))
    )
    assert delta > 0, f"{arch}: train step was a no-op"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_decode_consistency(arch):
    """Prefill + 1 decode step == full forward at the last position."""
    cfg = reduced_config(get_config(arch))
    if cfg.frontend == "patch":
        cfg = dataclasses.replace(cfg, n_frontend_tokens=0)  # decode is text-only
    lm = LM(cfg)
    params = lm.init(jax.random.key(1))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(BATCH, SEQ)), jnp.int32)

    full, _ = lm.forward(params, toks)
    _, cache = lm.prefill(params, toks[:, :-1], SEQ)
    dec, _ = lm.decode_step(params, cache, toks[:, -1:], jnp.asarray(SEQ - 1, jnp.int32))
    err = float(jnp.max(jnp.abs(dec[:, 0] - full[:, -1])))
    assert err < 5e-3, f"{arch}: decode mismatch {err}"


@pytest.mark.parametrize("arch", ["recurrentgemma-2b", "mixtral-8x7b", "mamba2-370m"])
def test_smoke_long_decode_state_bounded(arch):
    """Sub-quadratic archs: cache memory must not scale with max_len."""
    cfg = reduced_config(get_config(arch))
    lm = LM(cfg)
    small = lm.init_cache(1, 64)
    big = lm.init_cache(1, 4096)
    small_b = sum(x.size for x in jax.tree.leaves(small))
    big_b = sum(x.size for x in jax.tree.leaves(big))
    if get_config(arch).subquadratic and cfg.family in ("hybrid", "ssm"):
        assert big_b == small_b, f"{arch}: state grows with context"
    else:  # SWA dense/moe: bounded by window
        assert big_b <= small_b * (4096 // 64), arch


def test_full_configs_match_assignment():
    """The exact published numbers from the assignment block."""
    spec = {
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "mamba2-370m": (48, 1024, 1, 1, 0, 50280),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
    }
    for arch, (nl, dm, nh, kv, dff, v) in spec.items():
        cfg = get_config(arch)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab_size)
        assert got == (nl, dm, nh, kv, dff, v), (arch, got)
    moe = get_config("qwen3-moe-235b-a22b")
    assert (moe.n_experts, moe.experts_per_token) == (128, 8)
    mix = get_config("mixtral-8x7b")
    assert (mix.n_experts, mix.experts_per_token) == (8, 2)
    assert get_config("mamba2-370m").ssm_state == 128
