"""Time-travel reads: ``as_of``/``versions_since`` across the whole stack.

``published_at`` rides each snapshot on the tenant's own timeline (the
event-time watermark for windowed tenants), so a reader can replay the
exact sketch that was live at any retained instant.  That history must
survive every way a snapshot can move: store ``save``/``load``, pipeline
checkpoints, and a cluster rebalance (``scale_to`` tenant export/import)
— bit-identically, timestamps included.
"""
import jax
import numpy as np
import pytest

from repro.cluster.cell import PipelineCell
from repro.cluster.router import ClusterRouter
from repro.query.store import SketchStore
from repro.runtime.pipeline import StreamingPipeline
from repro.runtime.policies import EveryKSteps, OnWindowClose

D = 8


@pytest.fixture(scope="module")
def mesh():
    return jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))


def _fill(store, tenant, stamps):
    rng = np.random.default_rng(hash(tenant) % 2**31)
    return [
        store.publish(
            tenant,
            rng.normal(size=(4, D)).astype(np.float32),
            frob=float(i + 1),
            eps=0.25,
            n_seen=8 * (i + 1),
            meta={"i": i},
            published_at=t,
        )
        for i, t in enumerate(stamps)
    ]


def _history(store, tenant):
    """The full retained timeline as comparable tuples (matrix bytes incl.)."""
    return [
        (s.version, s.published_at, s.frob, s.n_seen, dict(s.meta),
         s.matrix.tobytes())
        for s in store.versions_since(tenant, 0)
    ]


def test_as_of_picks_newest_at_or_before_with_tie_to_higher_version():
    store = SketchStore()
    snaps = _fill(store, "a", [1.0, 3.0, 3.0, 7.0])
    assert store.as_of("a", 1.0).version == snaps[0].version
    assert store.as_of("a", 2.9).version == snaps[0].version
    # tie on published_at resolves to the higher (newer) version
    assert store.as_of("a", 3.0).version == snaps[2].version
    assert store.as_of("a", 100.0).version == snaps[3].version
    with pytest.raises(KeyError):
        store.as_of("a", 0.5)  # before the oldest retained snapshot
    with pytest.raises(KeyError):
        store.as_of("ghost", 1.0)
    # versions_since is the replica-sync face of the same shelf
    assert [s.version for s in store.versions_since("a", 0)] == [1, 2, 3, 4]
    assert [s.version for s in store.versions_since("a", 2)] == [3, 4]
    assert store.versions_since("ghost", 0) == []


def test_retain_bound_ages_out_the_oldest_timestamps():
    store = SketchStore(retain=2)
    _fill(store, "a", [1.0, 2.0, 3.0])
    with pytest.raises(KeyError):
        store.as_of("a", 1.5)  # version 1 (t=1.0) aged out
    assert store.as_of("a", 2.0).version == 2


def test_store_save_load_round_trips_history_bit_identically(tmp_path):
    store = SketchStore()
    _fill(store, "a", [1.0, 3.0, 9.0])
    _fill(store, "b", [2.0, 2.0])
    store.save(str(tmp_path / "store"))
    loaded = SketchStore.load(str(tmp_path / "store"))
    for tenant in ("a", "b"):
        assert _history(loaded, tenant) == _history(store, tenant)
    # time-travel answers agree on the restored store, counter included
    assert loaded.as_of("a", 4.0).version == store.as_of("a", 4.0).version
    loaded.publish("a", np.zeros((1, D)), frob=0.0, eps=0.1, published_at=10.0)
    assert loaded.latest_version("a") == store.latest_version("a") + 1


def test_pipeline_checkpoint_preserves_windowed_timeline(mesh, tmp_path):
    rng = np.random.default_rng(0)
    pipe = StreamingPipeline(mesh, eps=0.25)
    pipe.add_windowed_tenant(
        "w", kind="matrix", d=D, window=8.0, buckets=4, policy=OnWindowClose()
    )
    for t in range(16):
        pipe.ingest("w", rng.normal(size=(4, D)).astype(np.float32), ts=float(t))
    assert len(pipe.store.versions("w")) > 1
    pipe.save(str(tmp_path / "pipe"))
    loaded = StreamingPipeline.load(str(tmp_path / "pipe"), mesh)
    assert _history(loaded.store, "w") == _history(pipe.store, "w")
    probe = pipe.store.versions_since("w", 0)[1].published_at
    assert loaded.store.as_of("w", probe).version == pipe.store.as_of("w", probe).version
    pipe.close(), loaded.close()


def test_scale_to_moves_tenants_with_history_and_timestamps_intact(mesh):
    """A rebalance exports/imports whole tenant timelines: every retained
    version, its ``published_at``, and its bytes land on the destination
    cell unchanged, and ``as_of`` answers are identical before/after."""
    rng = np.random.default_rng(1)
    cells = [
        PipelineCell(f"cell-{i}", mesh, eps=0.25, policy=EveryKSteps(1))
        for i in range(2)
    ]
    router = ClusterRouter(cells)
    tenants = [f"w{i}" for i in range(6)]
    for name in tenants:
        router.add_windowed_tenant(
            name, kind="matrix", d=D, window=8.0, buckets=4,
            policy=OnWindowClose(),
        )
        for t in range(16):
            router.ingest(name, rng.normal(size=(4, D)).astype(np.float32),
                          ts=float(t))
    before = {
        name: _history(router.cell_for(name).store, name) for name in tenants
    }
    assert all(len(h) > 1 for h in before.values())

    grown = cells + [PipelineCell("cell-2", mesh, eps=0.25, policy=EveryKSteps(1))]
    plan = router.scale_to(grown)
    assert plan.moves, "ring growth moved nothing; test is vacuous"
    for move in plan.moves:
        assert router.placement()[move.tenant] == "cell-2"
    for name in tenants:
        assert _history(router.cell_for(name).store, name) == before[name]
        # time travel keeps working on the new owner, mid-timeline
        probe = before[name][1][1]
        assert router.cell_for(name).store.as_of(name, probe).version == \
            before[name][1][0]
    # moved windowed tenants keep ingesting on their own event timeline
    for name in tenants:
        router.ingest(name, rng.normal(size=(4, D)).astype(np.float32), ts=16.0)
        assert router.cell_for(name).pipeline.tracker(name).watermark() == 16.0
    router.close()
